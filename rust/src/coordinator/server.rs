//! Threaded serving front-end: a scheduler thread drives the continuous
//! batcher over engine sessions; clients submit requests through a bounded
//! channel and receive completions on another.
//!
//! Each active session owns a paged KV cache drawing from the engine's
//! shared page pool; the block-sparse weights live in one `Arc<Engine>`.
//! Decode rounds touch every active session once (continuous batching),
//! so short requests retire early and free their slot — and their KV
//! pages — for waiting requests: the Orca/vLLM scheduling shape, with the
//! paper's sparse MLP on the hot path. Admission is gated on pool
//! capacity (prompt pages + one decode step); prompts that could never
//! fit are answered with error completions immediately, and a session
//! whose pool runs dry mid-stream retires cleanly with its partial
//! output.
//!
//! With [`BatcherConfig::batched`] (the default), each round makes **one**
//! [`Engine::decode_batch`] call over all prefilled sessions, so every
//! projection/MLP/LM-head multiply runs as a single `(B × d_model)` packed
//! GEMM or BSpMM instead of B GEMV chains. Ragged batches (sessions
//! finishing mid-round) simply shrink B the next round. Errors are
//! isolated per session: a failed batched round falls back to per-session
//! sequential decode so one bad session can't poison the others, and a
//! session whose KV cache fills up retires with the tokens it has.
//! On [`Coordinator::stop`], queued-but-unadmitted requests and in-flight
//! sessions are drained into error completions — a client blocked on
//! [`Coordinator::next_completion`] always gets an answer.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::router::{Admit, Batcher, BatcherConfig, Request};
use crate::model::engine::{Engine, KvCache};

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The [`Request::id`] this completion answers.
    pub id: u64,
    /// Generated tokens (possibly partial when `error` is set).
    pub tokens: Vec<u32>,
    /// Seconds spent waiting for a batch slot.
    pub queue_secs: f64,
    /// Seconds from submission to the first generated token.
    pub ttft_secs: f64,
    /// Seconds from submission to completion.
    pub e2e_secs: f64,
    /// Why the request failed (prefill error, shutdown); `None` = success.
    pub error: Option<String>,
}

struct Timing {
    submitted: Instant,
    admitted: Option<Instant>,
    first_token: Option<Instant>,
}

/// Handle to a running serving coordinator: submit requests, receive
/// completions, read metrics, stop the scheduler.
pub struct Coordinator {
    tx: SyncSender<Request>,
    completions: Receiver<Completion>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServeMetrics>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the scheduler over an engine.
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Coordinator {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.max_queue);
        let (ctx, crx) = mpsc::channel::<Completion>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServeMetrics::new()));
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            scheduler_loop(engine, cfg, rx, ctx, stop2, metrics2);
        });
        Coordinator {
            tx,
            completions: crx,
            stop,
            metrics,
            worker: Some(worker),
        }
    }

    /// Submit a request; `Err` = queue full (backpressure) or shut down.
    pub fn submit(&self, req: Request) -> Result<()> {
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => anyhow::bail!("queue full, rejected request {}", r.id),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }

    /// Block for the next completion.
    pub fn next_completion(&self, timeout: Duration) -> Option<Completion> {
        self.completions.recv_timeout(timeout).ok()
    }

    /// One-line digest of the serving metrics so far.
    pub fn metrics_summary(&self) -> String {
        self.metrics.lock().unwrap().summary()
    }

    /// Decode throughput since startup (tokens/s).
    pub fn throughput(&self) -> f64 {
        self.metrics.lock().unwrap().throughput()
    }

    /// Mean sessions per decode round (continuous-batch occupancy).
    pub fn mean_round_batch(&self) -> f64 {
        self.metrics.lock().unwrap().mean_round_batch()
    }

    /// Stop the scheduler and wait for it to exit. Requests still queued
    /// or in flight are answered with error completions, never dropped.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

fn scheduler_loop(
    engine: Arc<Engine>,
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    ctx: Sender<Completion>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServeMetrics>>,
) {
    let mut batcher = Batcher::new(cfg);
    let mut caches: HashMap<u64, KvCache> = HashMap::new();
    let mut timing: HashMap<u64, Timing> = HashMap::new();
    // ids answered with an error completion at prefill time; retirement
    // must not send a second (bogus success) completion for them
    let mut errored: std::collections::HashSet<u64> = std::collections::HashSet::new();
    while !stop.load(Ordering::Relaxed) {
        // drain the submission channel into the waiting queue
        loop {
            match rx.recv_timeout(if batcher.idle() {
                Duration::from_millis(20)
            } else {
                Duration::ZERO
            }) {
                Ok(req) => {
                    let id = req.id;
                    // ids key the KV-cache and timing maps; a duplicate of
                    // a live request would corrupt both — reject it
                    if timing.contains_key(&id) {
                        ctx.send(Completion {
                            id,
                            tokens: Vec::new(),
                            queue_secs: 0.0,
                            ttft_secs: 0.0,
                            e2e_secs: 0.0,
                            error: Some(format!("duplicate request id {id} still in flight")),
                        })
                        .ok();
                        continue;
                    }
                    timing.insert(
                        id,
                        Timing {
                            submitted: Instant::now(),
                            admitted: None,
                            first_token: None,
                        },
                    );
                    if !batcher.enqueue(req) {
                        // bounded-queue overflow (should not happen: the
                        // channel is the same size) — answer with an error
                        // completion rather than dropping the request
                        timing.remove(&id);
                        ctx.send(Completion {
                            id,
                            tokens: Vec::new(),
                            queue_secs: 0.0,
                            ttft_secs: 0.0,
                            e2e_secs: 0.0,
                            error: Some("waiting queue full".into()),
                        })
                        .ok();
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if batcher.idle() {
                        return;
                    }
                    break;
                }
            }
        }

        if batcher.idle() {
            continue;
        }

        // admit new sessions against KV pool capacity: a session needs
        // pages for its prompt plus one decode step before it can make
        // progress. While pages are merely busy the head of the queue
        // *defers* (FIFO — later requests don't jump it); a prompt that
        // could never fit the pool is *refused* and answered with an
        // error completion right away. Pages the in-flight sessions need
        // for *their* next decode step are reserved out of the admission
        // budget first — otherwise a new prefill could grab the last free
        // page at an in-flight session's page boundary and silently
        // truncate it.
        let kv_pool = engine.kv_pool();
        let reserve: usize = caches
            .values()
            .map(|c| engine.kv_pages_for(c.len + 1).saturating_sub(c.pages_held()))
            .sum();
        let mut budget = kv_pool.available_pages().map(|a| a.saturating_sub(reserve));
        let (admitted, refused) = batcher.admit_where(|req| {
            let needed = engine.kv_pages_for(req.prompt.len().max(1) + 1);
            if kv_pool.capacity_pages().is_some_and(|cap| needed > cap) {
                return Admit::Refuse;
            }
            match budget {
                None => Admit::Grant,
                Some(avail) if needed <= avail => {
                    budget = Some(avail - needed);
                    Admit::Grant
                }
                Some(_) => Admit::Defer,
            }
        });
        for req in refused {
            let needed = engine.kv_pages_for(req.prompt.len().max(1) + 1);
            // the request may have queued for a while before reaching the
            // front and being refused — report that wait, not 0
            let waited = timing
                .remove(&req.id)
                .map(|t| t.submitted.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            metrics.lock().unwrap().kv_refused += 1;
            ctx.send(Completion {
                id: req.id,
                tokens: Vec::new(),
                queue_secs: waited,
                ttft_secs: 0.0,
                e2e_secs: waited,
                error: Some(format!(
                    "prompt needs {needed} KV pages but the pool capacity is {} pages",
                    kv_pool.capacity_pages().unwrap_or(0)
                )),
            })
            .ok();
        }

        // prefill the admitted sessions
        for idx in admitted {
            let s = &mut batcher.active_mut()[idx];
            let id = s.req.id;
            if let Some(t) = timing.get_mut(&id) {
                t.admitted = Some(Instant::now());
            }
            let mut cache = engine.new_cache();
            match engine.prefill(&s.req.prompt, &mut cache) {
                Ok(logits) => {
                    let tok = Engine::argmax(&logits);
                    s.output.push(tok);
                    s.prefilled = true;
                    if let Some(t) = timing.get_mut(&id) {
                        t.first_token = Some(Instant::now());
                    }
                    caches.insert(id, cache);
                }
                Err(e) => {
                    ctx.send(Completion {
                        id,
                        tokens: vec![],
                        queue_secs: 0.0,
                        ttft_secs: 0.0,
                        e2e_secs: 0.0,
                        error: Some(e.to_string()),
                    })
                    .ok();
                    errored.insert(id);
                    s.req.max_new = 0; // force retirement with no output
                    s.prefilled = true;
                }
            }
        }

        // one continuous-batching decode round: every prefilled, unfinished
        // session with KV headroom takes exactly one step
        let round_t0 = Instant::now();
        let max_seq = engine.config().max_seq;
        let mut round_ids: Vec<u64> = Vec::new();
        let mut round_tokens: Vec<u32> = Vec::new();
        for s in batcher.active_mut().iter_mut() {
            if !s.prefilled || s.finished() {
                continue;
            }
            if caches.get(&s.req.id).map(|c| c.len >= max_seq).unwrap_or(true) {
                // KV exhausted → finish with the tokens we have
                s.req.max_new = s.output.len();
                continue;
            }
            round_ids.push(s.req.id);
            round_tokens.push(*s.output.last().unwrap());
        }
        if !round_ids.is_empty() {
            let mut decoded: Vec<Option<Vec<f32>>> = vec![None; round_ids.len()];
            if cfg.batched {
                // stack the round's sessions into one decode_batch call —
                // a single (B × d_model) GEMM/BSpMM per projection
                let mut round_caches: Vec<KvCache> =
                    round_ids.iter().map(|id| caches.remove(id).unwrap()).collect();
                match engine.decode_batch(&round_tokens, &mut round_caches) {
                    Ok(all) => {
                        for (slot, logits) in decoded.iter_mut().zip(all) {
                            *slot = Some(logits);
                        }
                    }
                    Err(e) => {
                        // loud: a failing batched round silently costing a
                        // sequential fallback every iteration is exactly the
                        // regression the serve A/B exists to catch
                        metrics.lock().unwrap().batched_fallbacks += 1;
                        crate::log_warn!(
                            "coordinator",
                            "decode_batch failed ({} sessions), falling back to sequential: {e}",
                            round_ids.len()
                        );
                    }
                }
                for (id, c) in round_ids.iter().zip(round_caches) {
                    caches.insert(*id, c);
                }
            }
            // sequential path: the A/B baseline, and the per-session
            // fallback after a failed batched round (error isolation — one
            // bad session must not take down its batchmates)
            for (j, id) in round_ids.iter().enumerate() {
                if decoded[j].is_none() {
                    if let Ok(logits) = engine.decode(round_tokens[j], caches.get_mut(id).unwrap())
                    {
                        decoded[j] = Some(logits);
                    }
                }
            }
            // apply results in active order (round_ids preserves it)
            let mut produced = 0usize;
            let mut j = 0;
            for s in batcher.active_mut().iter_mut() {
                if j < round_ids.len() && s.req.id == round_ids[j] {
                    match decoded[j].take() {
                        Some(logits) => {
                            s.output.push(Engine::argmax(&logits));
                            produced += 1;
                        }
                        // session failed even sequentially → retire with
                        // whatever it has
                        None => s.req.max_new = s.output.len(),
                    }
                    j += 1;
                }
            }
            metrics.lock().unwrap().record_round(
                round_ids.len(),
                round_t0.elapsed().as_secs_f64(),
                produced,
            );
        }

        // snapshot KV residency (pool high-water travels with it, so the
        // peak the summary reports is the pool's own, not a re-derivation)
        metrics.lock().unwrap().record_kv(
            kv_pool.pages_in_use(),
            kv_pool.high_water_pages(),
            kv_pool.resident_bytes(),
        );

        // retire finished sessions
        for s in batcher.end_round() {
            let id = s.req.id;
            caches.remove(&id);
            if errored.remove(&id) {
                timing.remove(&id);
                continue; // already answered with an error completion
            }
            let t = timing.remove(&id);
            let now = Instant::now();
            let (queue_secs, ttft_secs, e2e_secs) = match &t {
                Some(t) => (
                    t.admitted
                        .map(|a| (a - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    t.first_token
                        .map(|f| (f - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    (now - t.submitted).as_secs_f64(),
                ),
                None => (0.0, 0.0, 0.0),
            };
            metrics.lock().unwrap().record_request(
                queue_secs,
                ttft_secs,
                e2e_secs,
                s.req.prompt.len(),
                s.output.len(),
            );
            ctx.send(Completion {
                id,
                tokens: s.output,
                queue_secs,
                ttft_secs,
                e2e_secs,
                error: None,
            })
            .ok();
        }
        // refresh the gauges after retirement freed caches, so an
        // end-of-run summary shows the pages actually still held (the
        // peak recorded above is unaffected)
        metrics.lock().unwrap().record_kv(
            kv_pool.pages_in_use(),
            kv_pool.high_water_pages(),
            kv_pool.resident_bytes(),
        );
    }

    // shutdown: drain everything still pending into error completions so a
    // client blocked on next_completion can never hang on a stopped
    // coordinator — requests sitting in the channel, queued-but-unadmitted
    // requests, and in-flight sessions (which keep their partial tokens)
    let stopped = |id: u64, tokens: Vec<u32>| Completion {
        id,
        tokens,
        queue_secs: 0.0,
        ttft_secs: 0.0,
        e2e_secs: 0.0,
        error: Some("coordinator stopped before completion".into()),
    };
    while let Ok(req) = rx.try_recv() {
        ctx.send(stopped(req.id, Vec::new())).ok();
    }
    for req in batcher.drain_waiting() {
        ctx.send(stopped(req.id, Vec::new())).ok();
    }
    for s in batcher.take_active() {
        // end_round() retires finished sessions every iteration, so
        // anything still active here is necessarily unfinished
        caches.remove(&s.req.id);
        ctx.send(stopped(s.req.id, s.output)).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::engine::MlpMode;
    use crate::model::kv::KvOptions;
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn tiny_engine() -> Arc<Engine> {
        tiny_engine_with_kv(KvOptions::default())
    }

    fn tiny_engine_with_kv(kv: KvOptions) -> Arc<Engine> {
        let cfg = NativeConfig {
            name: "t".into(),
            kind: ModelKind::Llama,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 32,
            block: 8,
        };
        let mut rng = Rng::new(1);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        Arc::new(Engine::new_with_kv(cfg, &s, &BTreeMap::new(), MlpMode::Sparse, kv).unwrap())
    }

    #[test]
    fn serves_batch_of_requests_end_to_end() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 16,
                ..BatcherConfig::default()
            },
        );
        let n = 8;
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 5,
                    eos: None,
                })
                .unwrap();
        }
        let mut done = Vec::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .expect("completion");
            assert!(c.error.is_none(), "{:?}", c.error);
            assert_eq!(c.tokens.len(), 5);
            assert!(c.e2e_secs >= c.ttft_secs);
            done.push(c.id);
        }
        done.sort_unstable();
        assert_eq!(done, (0..n).collect::<Vec<_>>());
        coord.stop();
    }

    #[test]
    fn identical_prompts_get_identical_outputs() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        for i in 0..2 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![4, 4, 4],
                    max_new: 6,
                    eos: None,
                })
                .unwrap();
        }
        let a = coord.next_completion(Duration::from_secs(30)).unwrap();
        let b = coord.next_completion(Duration::from_secs(30)).unwrap();
        assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
        coord.stop();
    }

    #[test]
    fn overlong_prompt_reports_error_exactly_once() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1; 100],
                max_new: 4,
                eos: None,
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).unwrap();
        assert!(c.error.is_some());
        // no spurious second completion for the same request
        assert!(coord.next_completion(Duration::from_millis(300)).is_none());
        coord.stop();
    }

    #[test]
    fn batched_and_sequential_rounds_serve_identical_tokens() {
        let mut answers: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
        for batched in [true, false] {
            let engine = tiny_engine();
            let mut coord = Coordinator::start(
                engine,
                BatcherConfig {
                    max_batch: 4,
                    max_queue: 16,
                    batched,
                },
            );
            for i in 0..6u64 {
                coord
                    .submit(Request {
                        id: i,
                        prompt: (0..2 + i as usize % 3).map(|j| (3 + i as u32 + j as u32) % 32).collect(),
                        max_new: 3 + i as usize % 4,
                        eos: None,
                    })
                    .unwrap();
            }
            let mut done = Vec::new();
            for _ in 0..6 {
                let c = coord.next_completion(Duration::from_secs(30)).expect("completion");
                assert!(c.error.is_none(), "{:?}", c.error);
                done.push((c.id, c.tokens));
            }
            done.sort_by_key(|(id, _)| *id);
            coord.stop();
            answers.push(done);
        }
        assert_eq!(
            answers[0], answers[1],
            "batched and sequential decode rounds must serve bit-identical greedy streams"
        );
    }

    #[test]
    fn duplicate_live_id_is_rejected_with_error_completion() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 1,
                max_queue: 8,
                ..BatcherConfig::default()
            },
        );
        // same id twice while the first is still live
        for _ in 0..2 {
            coord
                .submit(Request {
                    id: 42,
                    prompt: vec![1, 2, 3],
                    max_new: 6,
                    eos: None,
                })
                .unwrap();
        }
        // both submissions must be answered — served, or rejected as a
        // duplicate — and the scheduler must survive (no unwrap panic on
        // the shared id in the batched round)
        let mut oks = 0;
        for _ in 0..2 {
            let c = coord.next_completion(Duration::from_secs(30)).expect("completion");
            assert_eq!(c.id, 42);
            if c.error.is_none() {
                assert_eq!(c.tokens.len(), 6);
                oks += 1;
            }
        }
        assert!(oks >= 1, "at least one of the duplicates must be served");
        // scheduler still alive and serving
        coord
            .submit(Request {
                id: 7,
                prompt: vec![4, 5],
                max_new: 2,
                eos: None,
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).expect("completion");
        assert_eq!((c.id, c.error), (7, None));
        coord.stop();
    }

    /// A pool sized for ~2 concurrent sessions must still serve an
    /// 8-request load: admission defers (FIFO) until retiring sessions
    /// free pages, and every request completes without error.
    #[test]
    fn pool_constrained_serving_completes_all_requests() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 8,
            // each session: 3-token prompt + 5 decodes = 8 positions = 1
            // page; cap at 2 pages so at most 2 sessions hold KV at once
            pool_pages: Some(2),
        });
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 4, // batcher would admit 4; the pool says 2
                max_queue: 16,
                ..BatcherConfig::default()
            },
        );
        let n = 8u64;
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 5,
                    eos: None,
                })
                .unwrap();
        }
        let mut done = std::collections::HashSet::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .expect("completion");
            assert!(c.error.is_none(), "request {}: {:?}", c.id, c.error);
            assert_eq!(c.tokens.len(), 5);
            assert!(done.insert(c.id));
        }
        assert_eq!(done.len() as u64, n);
        // the pool high-water mark is visible in the round summary
        let s = coord.metrics_summary();
        assert!(s.contains("peak 2"), "{s}");
        coord.stop();
    }

    /// A prompt that could never fit the pool is refused at admission
    /// with a clean error completion (the coordinator's error-isolation
    /// path), and the scheduler keeps serving everyone else.
    #[test]
    fn impossible_prompt_refused_with_pool_error() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 4,
            pool_pages: Some(2), // 8 positions total
        });
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1; 10], // needs 3 pages for prompt+1 > cap 2
                max_new: 4,
                eos: None,
            })
            .unwrap();
        coord
            .submit(Request {
                id: 1,
                prompt: vec![1, 2], // fits
                max_new: 2,
                eos: None,
            })
            .unwrap();
        let mut errors = 0;
        let mut served = 0;
        for _ in 0..2 {
            let c = coord.next_completion(Duration::from_secs(30)).expect("completion");
            match (c.id, c.error) {
                (0, Some(e)) => {
                    assert!(e.contains("KV pages"), "{e}");
                    errors += 1;
                }
                (1, None) => {
                    assert_eq!(c.tokens.len(), 2);
                    served += 1;
                }
                other => panic!("unexpected completion {other:?}"),
            }
        }
        assert_eq!((errors, served), (1, 1));
        assert!(coord.metrics_summary().contains("kv_refused=1"));
        coord.stop();
    }

    #[test]
    fn stop_drains_queued_requests_into_error_completions() {
        let engine = tiny_engine();
        let n = 12u64;
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 1,
                max_queue: 32,
                ..BatcherConfig::default()
            },
        );
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 8,
                    eos: None,
                })
                .unwrap();
        }
        // stop immediately: most requests are still queued or in flight
        coord.stop();
        let mut seen = std::collections::HashSet::new();
        while let Some(c) = coord.next_completion(Duration::from_millis(500)) {
            assert!(seen.insert(c.id), "duplicate completion for {}", c.id);
            if c.error.is_some() {
                // drained requests carry the shutdown error
                assert!(c.tokens.len() < 8);
            }
        }
        assert_eq!(
            seen.len() as u64,
            n,
            "every submitted request must receive exactly one completion"
        );
    }
}
