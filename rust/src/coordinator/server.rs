//! Threaded serving front-end: a scheduler thread drives the continuous
//! batcher over engine sessions; clients submit requests through a bounded
//! channel and receive completions on another.
//!
//! Each active session owns a paged KV cache drawing from the engine's
//! shared page pool; the block-sparse weights live in one `Arc<Engine>`.
//! Decode rounds touch every active session once (continuous batching),
//! so short requests retire early and free their slot — and their KV
//! pages — for waiting requests: the Orca/vLLM scheduling shape, with the
//! paper's sparse MLP on the hot path. Admission is gated on pool
//! capacity (prompt pages + one decode step); prompts that could never
//! fit are answered with error completions immediately, and a session
//! whose pool runs dry mid-stream retires cleanly with its partial
//! output.
//!
//! With [`BatcherConfig::batched`] (the default), each round makes **one**
//! [`Engine::decode_batch`] call over all prefilled sessions, so every
//! projection/MLP/LM-head multiply runs as a single `(B × d_model)` packed
//! GEMM or BSpMM instead of B GEMV chains. Ragged batches (sessions
//! finishing mid-round) simply shrink B the next round.
//!
//! # Supervision (see ARCHITECTURE.md "Failure domains & recovery")
//!
//! The scheduler is a *supervised* runtime with three nested failure
//! domains, each isolated from the next:
//!
//! 1. **Round**: every batched decode round runs under `catch_unwind`. A
//!    panicking or failing round falls back to per-session sequential
//!    decode; a *transient* round error is first retried a bounded number
//!    of times with jittered backoff ([`BatcherConfig::round_retries`]).
//! 2. **Session**: each sequential decode step runs under its own
//!    `catch_unwind`. A panicking session retires with an error completion
//!    (partial tokens attached) — it cannot take down its batchmates.
//! 3. **Scheduler**: the whole loop runs under a watchdog `catch_unwind`
//!    in the worker thread. If the scheduler itself dies, the watchdog
//!    fails every queued and in-flight request with an error completion
//!    instead of hanging clients, then drops the completion channel so
//!    [`Coordinator::next_completion`] reports
//!    [`CompletionWait::Disconnected`].
//!
//! Per-request deadlines ([`Request::deadline_ms`]) are enforced at the
//! admission sweep (queued past deadline → expired) and at every round
//! boundary (in-flight past deadline → retired with partial output), so a
//! client waits at most one round past its deadline. A [`HealthState`]
//! gauge flips to Degraded under sustained round failures (hysteresis on
//! a strain counter) and sheds new arrivals at admission until rounds run
//! clean again. All of this is driven deterministically in tests by the
//! seeded fault injector ([`crate::util::faults::Faults`]); with no fault
//! plan armed every injection site is a single null-pointer check.
//!
//! On [`Coordinator::stop`], queued-but-unadmitted requests and in-flight
//! sessions are drained into error completions — a client blocked on
//! [`Coordinator::next_completion`] always gets an answer.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::router::{Admit, Batcher, BatcherConfig, Request};
use crate::model::engine::{Engine, KvCache};
use crate::util::faults::{FaultSite, Faults};
use crate::util::rng::Rng;

/// A finished request.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The [`Request::id`] this completion answers.
    pub id: u64,
    /// Generated tokens (possibly partial when `error` is set).
    pub tokens: Vec<u32>,
    /// Seconds spent waiting for a batch slot.
    pub queue_secs: f64,
    /// Seconds from submission to the first generated token.
    pub ttft_secs: f64,
    /// Seconds from submission to completion.
    pub e2e_secs: f64,
    /// Why the request failed (prefill error, deadline, shutdown);
    /// `None` = success.
    pub error: Option<String>,
}

/// Outcome of waiting for a completion — a timeout (the coordinator is
/// alive, just slow; wait again) is a different situation from a dead
/// coordinator (every completion that will ever arrive has arrived), and
/// conflating them as `None` made clients poll a corpse.
#[derive(Debug)]
pub enum CompletionWait {
    /// A completion arrived.
    Ready(Completion),
    /// Nothing arrived within the timeout; the scheduler is still running.
    TimedOut,
    /// The scheduler has exited (stop or watchdog) and the completion
    /// stream is fully drained — no further completions will ever arrive.
    Disconnected,
}

impl CompletionWait {
    /// The completion, if one arrived (`TimedOut`/`Disconnected` → `None`).
    pub fn ready(self) -> Option<Completion> {
        match self {
            CompletionWait::Ready(c) => Some(c),
            _ => None,
        }
    }

    /// `true` when the coordinator is gone for good.
    pub fn is_disconnected(&self) -> bool {
        matches!(self, CompletionWait::Disconnected)
    }
}

/// Coordinator health, exposed on [`Coordinator::health`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally.
    Healthy = 0,
    /// Sustained round failures/panics — new arrivals are shed at
    /// admission until rounds run clean again.
    Degraded = 1,
    /// Shutting down (stop requested or watchdog tripped); no new work.
    Draining = 2,
}

impl HealthState {
    fn from_u8(v: u8) -> HealthState {
        match v {
            0 => HealthState::Healthy,
            1 => HealthState::Degraded,
            _ => HealthState::Draining,
        }
    }
}

struct Timing {
    submitted: Instant,
    admitted: Option<Instant>,
    first_token: Option<Instant>,
}

/// Lock the metrics even if a caught panic poisoned the mutex — the
/// counters stay meaningful (a panic can at worst lose its own increment).
fn mlock(m: &Mutex<ServeMetrics>) -> MutexGuard<'_, ServeMetrics> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Handle to a running serving coordinator: submit requests, receive
/// completions, read metrics and health, stop the scheduler.
pub struct Coordinator {
    tx: SyncSender<Request>,
    completions: Receiver<Completion>,
    stop: Arc<AtomicBool>,
    metrics: Arc<Mutex<ServeMetrics>>,
    health: Arc<AtomicU8>,
    heartbeat: Arc<AtomicU64>,
    faults: Faults,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the scheduler over an engine (no fault injection).
    pub fn start(engine: Arc<Engine>, cfg: BatcherConfig) -> Coordinator {
        Coordinator::start_with_faults(engine, cfg, Faults::disabled())
    }

    /// Spawn the scheduler with a fault plan armed (chaos harness entry
    /// point; [`Faults::disabled`] makes this identical to
    /// [`Coordinator::start`]).
    pub fn start_with_faults(engine: Arc<Engine>, cfg: BatcherConfig, faults: Faults) -> Coordinator {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.max_queue);
        let (ctx, crx) = mpsc::channel::<Completion>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServeMetrics::new()));
        let health = Arc::new(AtomicU8::new(HealthState::Healthy as u8));
        // liveness counter: bumped once per scheduler iteration, read by
        // the fleet's stall detector (a frozen counter = a stuck replica)
        let heartbeat = Arc::new(AtomicU64::new(0));
        // ids received but not yet answered — the watchdog's drain list
        let inflight = Arc::new(Mutex::new(HashSet::<u64>::new()));
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let health2 = health.clone();
        let heartbeat2 = heartbeat.clone();
        let faults2 = faults.clone();
        let worker = std::thread::spawn(move || {
            let crashed = catch_unwind(AssertUnwindSafe(|| {
                scheduler_loop(
                    &engine, cfg, &rx, &ctx, &stop2, &metrics2, &health2, &heartbeat2,
                    &inflight, &faults2,
                );
            }))
            .is_err();
            if crashed {
                // watchdog: the scheduler died outside round/session
                // isolation. Fail everything pending so no client hangs,
                // then let ctx drop → clients see Disconnected.
                health2.store(HealthState::Draining as u8, Ordering::Relaxed);
                mlock(&metrics2).watchdog_trips += 1;
                let dead = |id: u64| Completion {
                    id,
                    tokens: Vec::new(),
                    queue_secs: 0.0,
                    ttft_secs: 0.0,
                    e2e_secs: 0.0,
                    error: Some("scheduler thread panicked; request abandoned".into()),
                };
                let mut failed = 0usize;
                while let Ok(req) = rx.try_recv() {
                    ctx.send(dead(req.id)).ok();
                    failed += 1;
                }
                let ids: Vec<u64> = {
                    let mut g = inflight.lock().unwrap_or_else(|e| e.into_inner());
                    g.drain().collect()
                };
                for id in ids {
                    ctx.send(dead(id)).ok();
                    failed += 1;
                }
                crate::log_warn!(
                    "coordinator",
                    "watchdog: scheduler thread panicked; failed {failed} pending request(s)"
                );
            }
        });
        Coordinator {
            tx,
            completions: crx,
            stop,
            metrics,
            health,
            heartbeat,
            faults,
            worker: Some(worker),
        }
    }

    /// Submit a request; `Err` = queue full (backpressure) or shut down.
    pub fn submit(&self, req: Request) -> Result<()> {
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(r)) => anyhow::bail!("queue full, rejected request {}", r.id),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }

    /// Wait for the next completion, distinguishing "nothing yet" from
    /// "the coordinator is gone and the stream is drained".
    pub fn next_completion(&self, timeout: Duration) -> CompletionWait {
        match self.completions.recv_timeout(timeout) {
            Ok(c) => CompletionWait::Ready(c),
            Err(RecvTimeoutError::Timeout) => CompletionWait::TimedOut,
            Err(RecvTimeoutError::Disconnected) => CompletionWait::Disconnected,
        }
    }

    /// Current health of the scheduler.
    pub fn health(&self) -> HealthState {
        HealthState::from_u8(self.health.load(Ordering::Relaxed))
    }

    /// Scheduler liveness counter: bumps once per loop iteration while the
    /// scheduler runs (an injected `heartbeat_drop` skips single bumps; an
    /// injected `replica_stall_ms` freezes it for the stall). A fleet's
    /// stall detector deposes a replica whose counter stops advancing.
    pub fn heartbeat(&self) -> u64 {
        self.heartbeat.load(Ordering::Relaxed)
    }

    /// Ask the scheduler to stop **without joining it** — the depose path
    /// for a stalled replica, where joining would block the fleet router
    /// for the length of the stall. The scheduler drains pending requests
    /// into error completions when it next wakes; [`Coordinator::stop`]
    /// (or drop) still joins eventually.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// The fault plan this coordinator was started with (fired/checked
    /// counters update live — the chaos harness reads them).
    pub fn faults(&self) -> &Faults {
        &self.faults
    }

    /// One-line digest of the serving metrics so far.
    pub fn metrics_summary(&self) -> String {
        mlock(&self.metrics).summary()
    }

    /// Timing-independent counter digest ([`ServeMetrics::invariant_digest`]).
    pub fn metrics_digest(&self) -> String {
        mlock(&self.metrics).invariant_digest()
    }

    /// Shared handle to the live metrics (fleet aggregation).
    pub(crate) fn metrics_arc(&self) -> Arc<Mutex<ServeMetrics>> {
        self.metrics.clone()
    }

    /// Decode throughput since startup (tokens/s).
    pub fn throughput(&self) -> f64 {
        mlock(&self.metrics).throughput()
    }

    /// Mean sessions per decode round (continuous-batch occupancy).
    pub fn mean_round_batch(&self) -> f64 {
        mlock(&self.metrics).mean_round_batch()
    }

    /// Stop the scheduler and wait for it to exit. Requests still queued
    /// or in flight are answered with error completions, never dropped.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
        self.health.store(HealthState::Draining as u8, Ordering::Relaxed);
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Backoff before retry `attempt` (1-based) of a failed decode round, in
/// microseconds: exponential in the attempt with a jitter draw from the
/// plan-forked RNG. Public so the schedule is pinned by tests and the
/// Python transliteration (`fleet_check.py`) byte-for-byte.
pub fn retry_backoff_us(attempt: usize, rng: &mut Rng) -> u64 {
    (100u64 << attempt.min(4)) + rng.below(200) as u64
}

#[allow(clippy::too_many_arguments)]
fn scheduler_loop(
    engine: &Engine,
    cfg: BatcherConfig,
    rx: &Receiver<Request>,
    ctx: &Sender<Completion>,
    stop: &AtomicBool,
    metrics: &Mutex<ServeMetrics>,
    health: &AtomicU8,
    heartbeat: &AtomicU64,
    inflight: &Mutex<HashSet<u64>>,
    faults: &Faults,
) {
    let mut batcher = Batcher::new(cfg);
    let mut caches: HashMap<u64, KvCache> = HashMap::new();
    let mut timing: HashMap<u64, Timing> = HashMap::new();
    // ids answered with an error completion before retirement (prefill
    // error, session panic, deadline); retirement must not send a second
    // (bogus success) completion for them
    let mut errored: HashSet<u64> = HashSet::new();
    // deterministic jitter for transient-round-failure backoff, forked
    // from the fault plan so retry schedules replay bit-for-bit under
    // BLAST_CHAOS_SEED (and per replica under Faults::fork)
    let mut retry_rng = faults.fork_rng("round_retry");
    // consecutive-bad-round pressure driving the health gauge: +1 per bad
    // round, -1 per clean one; Degraded at >= STRAIN_DEGRADED
    const STRAIN_DEGRADED: u32 = 3;
    const STRAIN_CAP: u32 = 6;
    let mut strain: u32 = 0;
    // answer a request and release its watchdog registration
    let send = |c: Completion| {
        inflight.lock().unwrap_or_else(|e| e.into_inner()).remove(&c.id);
        ctx.send(c).ok();
    };
    let deadline_passed = |t: &Timing, req: &Request| -> bool {
        req.deadline_ms
            .is_some_and(|d| t.submitted.elapsed() >= Duration::from_millis(d))
    };
    'serve: while !stop.load(Ordering::Relaxed) {
        // liveness heartbeat: one bump per iteration. An injected
        // heartbeat_drop skips this bump only — the scheduler is fine,
        // the counter just looks momentarily quiet (stall-detector noise).
        if !faults.fire(FaultSite::HeartbeatDrop) {
            heartbeat.fetch_add(1, Ordering::Relaxed);
        }
        // injected scheduler death: outside every catch_unwind below, so
        // only the watchdog in the worker thread can catch it
        if faults.fire(FaultSite::SchedulerPanic) {
            mlock(metrics).faults_injected += 1;
            panic!("injected scheduler_panic");
        }
        // injected replica death: identical mechanics, separate site so a
        // fleet chaos plan can kill replicas without also arming the
        // single-coordinator watchdog storm
        if faults.fire(FaultSite::ReplicaCrash) {
            mlock(metrics).faults_injected += 1;
            panic!("injected replica_crash");
        }
        // injected whole-scheduler freeze: the heartbeat stops advancing
        // for the stall — the straggler signature the fleet's stall
        // detector keys on (unlike decode_stall_ms, which only slows one
        // round and still bumps the heartbeat each iteration)
        if let Some(d) = faults.stall(FaultSite::ReplicaStallMs) {
            mlock(metrics).faults_injected += 1;
            std::thread::sleep(d);
        }
        // drain the submission channel into the waiting queue
        loop {
            match rx.recv_timeout(if batcher.idle() {
                Duration::from_millis(20)
            } else {
                Duration::ZERO
            }) {
                Ok(req) => {
                    let id = req.id;
                    // ids key the KV-cache and timing maps; a duplicate of
                    // a live request would corrupt both — reject it (raw
                    // send: the live copy keeps its watchdog registration)
                    if timing.contains_key(&id) {
                        ctx.send(Completion {
                            id,
                            tokens: Vec::new(),
                            queue_secs: 0.0,
                            ttft_secs: 0.0,
                            e2e_secs: 0.0,
                            error: Some(format!("duplicate request id {id} still in flight")),
                        })
                        .ok();
                        continue;
                    }
                    // load shedding: while Degraded, answering a request
                    // now with a cheap error beats queueing it behind a
                    // failing batch
                    if health.load(Ordering::Relaxed) == HealthState::Degraded as u8 {
                        mlock(metrics).shed += 1;
                        ctx.send(Completion {
                            id,
                            tokens: Vec::new(),
                            queue_secs: 0.0,
                            ttft_secs: 0.0,
                            e2e_secs: 0.0,
                            error: Some("coordinator degraded, shedding load".into()),
                        })
                        .ok();
                        continue;
                    }
                    inflight.lock().unwrap_or_else(|e| e.into_inner()).insert(id);
                    timing.insert(
                        id,
                        Timing {
                            submitted: Instant::now(),
                            admitted: None,
                            first_token: None,
                        },
                    );
                    if !batcher.enqueue(req) {
                        // bounded-queue overflow (should not happen: the
                        // channel is the same size) — answer with an error
                        // completion rather than dropping the request
                        timing.remove(&id);
                        send(Completion {
                            id,
                            tokens: Vec::new(),
                            queue_secs: 0.0,
                            ttft_secs: 0.0,
                            e2e_secs: 0.0,
                            error: Some("waiting queue full".into()),
                        });
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    if batcher.idle() {
                        break 'serve;
                    }
                    break;
                }
            }
        }

        if batcher.idle() {
            continue;
        }

        // expire queued requests already past their deadline — cheaper to
        // answer now than to prefill work nobody is waiting for
        for req in batcher.expire_where(|r| {
            timing.get(&r.id).map(|t| deadline_passed(t, r)).unwrap_or(false)
        }) {
            let waited = timing
                .remove(&req.id)
                .map(|t| t.submitted.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            mlock(metrics).deadline_misses += 1;
            send(Completion {
                id: req.id,
                tokens: Vec::new(),
                queue_secs: waited,
                ttft_secs: 0.0,
                e2e_secs: waited,
                error: Some(format!(
                    "deadline of {}ms exceeded while queued",
                    req.deadline_ms.unwrap_or(0)
                )),
            });
        }

        // admit new sessions against KV pool capacity: a session needs
        // pages for its prompt plus one decode step before it can make
        // progress. While pages are merely busy the head of the queue
        // *defers* (FIFO — later requests don't jump it); a prompt that
        // could never fit the pool is *refused* and answered with an
        // error completion right away. Pages the in-flight sessions need
        // for *their* next decode step are reserved out of the admission
        // budget first — otherwise a new prefill could grab the last free
        // page at an in-flight session's page boundary and silently
        // truncate it.
        let kv_pool = engine.kv_pool();
        let reserve: usize = caches
            .values()
            .map(|c| engine.kv_pages_for(c.len + 1).saturating_sub(c.pages_held()))
            .sum();
        let mut budget = kv_pool.available_pages().map(|a| a.saturating_sub(reserve));
        let (admitted, refused) = batcher.admit_where(|req| {
            let full = engine.kv_pages_for(req.prompt.len().max(1) + 1);
            if kv_pool.capacity_pages().is_some_and(|cap| full > cap) {
                // refusal stays on the *unshared* cost: a donor can retire
                // at any moment, and a request admitted only by grace of
                // someone else's pages would then be stuck forever
                return Admit::Refuse;
            }
            // a cache-hit prompt charges only its unshared tail: pages the
            // prefix index already holds are mapped, not allocated. The
            // probe runs fresh on every sweep, against the index as it is
            // *now* — so a Deferred request retried next round charges its
            // current tail, never re-charging pages that are already
            // resident (and, symmetrically, paying full price again if the
            // donor retired in between). If the donor vanishes between this
            // probe and the prefill, the prefill allocates the difference
            // or retires on clean pool exhaustion like any other session.
            let needed = full - kv_pool.probe_prefix(&req.prompt);
            match budget {
                None => Admit::Grant,
                Some(avail) if needed <= avail => {
                    budget = Some(avail - needed);
                    Admit::Grant
                }
                Some(_) => Admit::Defer,
            }
        });
        for req in refused {
            let needed = engine.kv_pages_for(req.prompt.len().max(1) + 1);
            // the request may have queued for a while before reaching the
            // front and being refused — report that wait, not 0
            let waited = timing
                .remove(&req.id)
                .map(|t| t.submitted.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            mlock(metrics).kv_refused += 1;
            send(Completion {
                id: req.id,
                tokens: Vec::new(),
                queue_secs: waited,
                ttft_secs: 0.0,
                e2e_secs: waited,
                error: Some(format!(
                    "prompt needs {needed} KV pages but the pool capacity is {} pages",
                    kv_pool.capacity_pages().unwrap_or(0)
                )),
            });
        }

        // prefill the admitted sessions
        for idx in admitted {
            let s = &mut batcher.active_mut()[idx];
            let id = s.req.id;
            if let Some(t) = timing.get_mut(&id) {
                t.admitted = Some(Instant::now());
            }
            let mut cache = engine.new_cache();
            let prefilled = if faults.fire(FaultSite::PrefillError) {
                mlock(metrics).faults_injected += 1;
                Err(anyhow::anyhow!("injected prefill_error"))
            } else {
                engine.prefill(&s.req.prompt, &mut cache)
            };
            match prefilled {
                Ok(logits) => {
                    let tok = Engine::argmax(&logits);
                    s.output.push(tok);
                    s.prefilled = true;
                    if let Some(t) = timing.get_mut(&id) {
                        t.first_token = Some(Instant::now());
                    }
                    caches.insert(id, cache);
                }
                Err(e) => {
                    send(Completion {
                        id,
                        tokens: vec![],
                        queue_secs: 0.0,
                        ttft_secs: 0.0,
                        e2e_secs: 0.0,
                        error: Some(e.to_string()),
                    });
                    errored.insert(id);
                    s.req.max_new = 0; // force retirement with no output
                    s.prefilled = true;
                }
            }
        }

        // one continuous-batching decode round: every prefilled, unfinished
        // session with KV headroom takes exactly one step
        let round_t0 = Instant::now();
        let max_seq = engine.config().max_seq;
        let mut round_ids: Vec<u64> = Vec::new();
        let mut round_tokens: Vec<u32> = Vec::new();
        for s in batcher.active_mut().iter_mut() {
            if !s.prefilled || s.finished() {
                continue;
            }
            if caches.get(&s.req.id).map(|c| c.len >= max_seq).unwrap_or(true) {
                // KV exhausted → finish with the tokens we have
                s.req.max_new = s.output.len();
                continue;
            }
            round_ids.push(s.req.id);
            round_tokens.push(*s.output.last().unwrap());
        }
        let mut round_bad = false;
        if !round_ids.is_empty() {
            // injected stall: models a slow round (deadline coverage)
            if let Some(d) = faults.stall(FaultSite::DecodeStallMs) {
                mlock(metrics).faults_injected += 1;
                std::thread::sleep(d);
            }
            let mut decoded: Vec<Option<Vec<f32>>> = vec![None; round_ids.len()];
            // sessions that panicked during sequential decode this round
            let mut panicked: HashSet<u64> = HashSet::new();
            if cfg.batched {
                // stack the round's sessions into one decode_batch call —
                // a single (B × d_model) GEMM/BSpMM per projection. The
                // whole round runs under catch_unwind: one poisoned
                // session must not kill the scheduler.
                let mut round_caches: Vec<KvCache> =
                    round_ids.iter().map(|id| caches.remove(id).unwrap()).collect();
                let mut attempt = 0usize;
                loop {
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        if faults.fire(FaultSite::DecodeRoundPanic) {
                            mlock(metrics).faults_injected += 1;
                            panic!("injected decode_round_panic");
                        }
                        if faults.fire(FaultSite::DecodeRoundError) {
                            mlock(metrics).faults_injected += 1;
                            anyhow::bail!("injected transient decode fault");
                        }
                        if faults.fire(FaultSite::KvPoolExhausted) {
                            mlock(metrics).faults_injected += 1;
                            anyhow::bail!("KV page pool exhausted (injected fault)");
                        }
                        engine.decode_batch(&round_tokens, &mut round_caches)
                    }));
                    match outcome {
                        Ok(Ok(all)) => {
                            for (slot, logits) in decoded.iter_mut().zip(all) {
                                *slot = Some(logits);
                            }
                            break;
                        }
                        Ok(Err(e)) => {
                            // pool exhaustion is deterministic — retrying
                            // cannot help; anything else gets a bounded
                            // retry with jittered backoff before we pay
                            // for a sequential fallback
                            let transient = !e.to_string().contains("exhausted");
                            if transient && attempt < cfg.round_retries {
                                attempt += 1;
                                mlock(metrics).round_retries += 1;
                                let backoff = retry_backoff_us(attempt, &mut retry_rng);
                                std::thread::sleep(Duration::from_micros(backoff));
                                continue;
                            }
                            round_bad = true;
                            // loud: a failing batched round silently
                            // costing a sequential fallback every iteration
                            // is exactly the regression the serve A/B
                            // exists to catch
                            mlock(metrics).batched_fallbacks += 1;
                            crate::log_warn!(
                                "coordinator",
                                "decode_batch failed ({} sessions), falling back to sequential: {e}",
                                round_ids.len()
                            );
                            break;
                        }
                        Err(_) => {
                            round_bad = true;
                            mlock(metrics).round_panics += 1;
                            crate::log_warn!(
                                "coordinator",
                                "decode round panicked ({} sessions); isolating per session",
                                round_ids.len()
                            );
                            break;
                        }
                    }
                }
                for (id, c) in round_ids.iter().zip(round_caches) {
                    caches.insert(*id, c);
                }
            }
            // sequential path: the A/B baseline, and the per-session
            // fallback after a failed batched round (error isolation — one
            // bad session must not take down its batchmates). Each step is
            // individually unwind-isolated: a panicking session retires
            // with an error completion below.
            for (j, id) in round_ids.iter().enumerate() {
                if decoded[j].is_some() {
                    continue;
                }
                let cache = caches.get_mut(id).unwrap();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if faults.fire(FaultSite::DecodeRoundPanic) {
                        mlock(metrics).faults_injected += 1;
                        panic!("injected session panic");
                    }
                    if faults.fire(FaultSite::KvPoolExhausted) {
                        mlock(metrics).faults_injected += 1;
                        anyhow::bail!("KV page pool exhausted (injected fault)");
                    }
                    engine.decode(round_tokens[j], cache)
                }));
                match outcome {
                    Ok(Ok(logits)) => decoded[j] = Some(logits),
                    // session failed cleanly → retires below with its
                    // partial output (success-with-partial semantics)
                    Ok(Err(_)) => {}
                    Err(_) => {
                        mlock(metrics).session_panics += 1;
                        panicked.insert(*id);
                    }
                }
            }
            if !panicked.is_empty() {
                round_bad = true;
            }
            // apply results in active order (round_ids preserves it)
            let mut produced = 0usize;
            let mut j = 0;
            for s in batcher.active_mut().iter_mut() {
                if j < round_ids.len() && s.req.id == round_ids[j] {
                    if panicked.contains(&s.req.id) {
                        // a panicking session retires NOW with an error
                        // completion carrying its partial tokens
                        let id = s.req.id;
                        let tokens = std::mem::take(&mut s.output);
                        s.req.max_new = 0; // finished() → retired below
                        errored.insert(id);
                        decoded[j] = None;
                        let (queue_secs, ttft_secs, e2e_secs) = timing
                            .get(&id)
                            .map(|t| {
                                (
                                    t.admitted
                                        .map(|a| (a - t.submitted).as_secs_f64())
                                        .unwrap_or(0.0),
                                    t.first_token
                                        .map(|f| (f - t.submitted).as_secs_f64())
                                        .unwrap_or(0.0),
                                    t.submitted.elapsed().as_secs_f64(),
                                )
                            })
                            .unwrap_or((0.0, 0.0, 0.0));
                        send(Completion {
                            id,
                            tokens,
                            queue_secs,
                            ttft_secs,
                            e2e_secs,
                            error: Some("session panicked during decode".into()),
                        });
                    } else {
                        match decoded[j].take() {
                            Some(logits) => {
                                s.output.push(Engine::argmax(&logits));
                                produced += 1;
                            }
                            // session failed even sequentially → retire
                            // with whatever it has
                            None => s.req.max_new = s.output.len(),
                        }
                    }
                    j += 1;
                }
            }
            mlock(metrics).record_round(
                round_ids.len(),
                round_t0.elapsed().as_secs_f64(),
                produced,
            );
            // health hysteresis: sustained bad rounds flip Degraded (shed
            // at admission); clean rounds walk it back to Healthy. Pool
            // pressure alone is NOT strain — deferral is normal operation.
            if round_bad {
                strain = (strain + 1).min(STRAIN_CAP);
            } else {
                strain = strain.saturating_sub(1);
            }
            let h = if strain >= STRAIN_DEGRADED {
                HealthState::Degraded
            } else {
                HealthState::Healthy
            };
            health.store(h as u8, Ordering::Relaxed);
        }

        // deadline enforcement at the round boundary: an in-flight session
        // past its deadline retires with partial output and a deadline
        // error — a client waits at most one round past the deadline
        for s in batcher.active_mut().iter_mut() {
            let id = s.req.id;
            if errored.contains(&id) || s.finished() {
                continue;
            }
            let Some(t) = timing.get(&id) else { continue };
            if deadline_passed(t, &s.req) {
                mlock(metrics).deadline_misses += 1;
                errored.insert(id);
                let tokens = std::mem::take(&mut s.output);
                let deadline = s.req.deadline_ms.unwrap_or(0);
                s.req.max_new = 0; // finished() → retired below
                send(Completion {
                    id,
                    tokens,
                    queue_secs: t
                        .admitted
                        .map(|a| (a - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    ttft_secs: t
                        .first_token
                        .map(|f| (f - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    e2e_secs: t.submitted.elapsed().as_secs_f64(),
                    error: Some(format!("deadline of {deadline}ms exceeded")),
                });
            }
        }

        // snapshot KV residency (pool high-water travels with it, so the
        // peak the summary reports is the pool's own, not a re-derivation)
        // and the prefix-sharing counters (all-zero with sharing off, so
        // the summary stays byte-identical to the unshared path)
        {
            let mut m = mlock(metrics);
            m.record_kv(
                kv_pool.pages_in_use(),
                kv_pool.high_water_pages(),
                kv_pool.resident_bytes(),
            );
            m.record_prefix(&kv_pool.prefix_stats(), kv_pool.capacity_pages());
            m.record_attn(engine.attn_stats());
        }

        // retire finished sessions
        for s in batcher.end_round() {
            let id = s.req.id;
            caches.remove(&id);
            if errored.remove(&id) {
                timing.remove(&id);
                continue; // already answered with an error completion
            }
            let t = timing.remove(&id);
            let now = Instant::now();
            let (queue_secs, ttft_secs, e2e_secs) = match &t {
                Some(t) => (
                    t.admitted
                        .map(|a| (a - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    t.first_token
                        .map(|f| (f - t.submitted).as_secs_f64())
                        .unwrap_or(0.0),
                    (now - t.submitted).as_secs_f64(),
                ),
                None => (0.0, 0.0, 0.0),
            };
            mlock(metrics).record_request(
                queue_secs,
                ttft_secs,
                e2e_secs,
                s.req.prompt.len(),
                s.output.len(),
            );
            send(Completion {
                id,
                tokens: s.output,
                queue_secs,
                ttft_secs,
                e2e_secs,
                error: None,
            });
        }
        // refresh the gauges after retirement freed caches, so an
        // end-of-run summary shows the pages actually still held (the
        // peak recorded above is unaffected)
        {
            let mut m = mlock(metrics);
            m.record_kv(
                kv_pool.pages_in_use(),
                kv_pool.high_water_pages(),
                kv_pool.resident_bytes(),
            );
            m.record_prefix(&kv_pool.prefix_stats(), kv_pool.capacity_pages());
            m.record_attn(engine.attn_stats());
        }
    }

    // shutdown: drain everything still pending into error completions so a
    // client blocked on next_completion can never hang on a stopped
    // coordinator — requests sitting in the channel, queued-but-unadmitted
    // requests, and in-flight sessions (which keep their partial tokens)
    health.store(HealthState::Draining as u8, Ordering::Relaxed);
    let stopped = |id: u64, tokens: Vec<u32>| Completion {
        id,
        tokens,
        queue_secs: 0.0,
        ttft_secs: 0.0,
        e2e_secs: 0.0,
        error: Some("coordinator stopped before completion".into()),
    };
    while let Ok(req) = rx.try_recv() {
        send(stopped(req.id, Vec::new()));
    }
    for req in batcher.drain_waiting() {
        send(stopped(req.id, Vec::new()));
    }
    for s in batcher.take_active() {
        // end_round() retires finished sessions every iteration, so
        // anything still active here is necessarily unfinished
        caches.remove(&s.req.id);
        send(stopped(s.req.id, s.output));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::engine::MlpMode;
    use crate::model::kv::KvOptions;
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn tiny_engine() -> Arc<Engine> {
        tiny_engine_with_kv(KvOptions::default())
    }

    fn tiny_engine_with_kv(kv: KvOptions) -> Arc<Engine> {
        let cfg = NativeConfig {
            name: "t".into(),
            kind: ModelKind::Llama,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 32,
            block: 8,
        };
        let mut rng = Rng::new(1);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        Arc::new(Engine::new_with_kv(cfg, &s, &BTreeMap::new(), MlpMode::Sparse, kv).unwrap())
    }

    #[test]
    fn serves_batch_of_requests_end_to_end() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 16,
                ..BatcherConfig::default()
            },
        );
        assert_eq!(coord.health(), HealthState::Healthy);
        let n = 8;
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 5,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut done = Vec::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            assert!(c.error.is_none(), "{:?}", c.error);
            assert_eq!(c.tokens.len(), 5);
            assert!(c.e2e_secs >= c.ttft_secs);
            done.push(c.id);
        }
        done.sort_unstable();
        assert_eq!(done, (0..n).collect::<Vec<_>>());
        coord.stop();
        assert_eq!(coord.health(), HealthState::Draining);
    }

    #[test]
    fn identical_prompts_get_identical_outputs() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        for i in 0..2 {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![4, 4, 4],
                    max_new: 6,
                    ..Default::default()
                })
                .unwrap();
        }
        let a = coord.next_completion(Duration::from_secs(30)).ready().unwrap();
        let b = coord.next_completion(Duration::from_secs(30)).ready().unwrap();
        assert_eq!(a.tokens, b.tokens, "greedy decode must be deterministic");
        coord.stop();
    }

    #[test]
    fn overlong_prompt_reports_error_exactly_once() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1; 100],
                max_new: 4,
                ..Default::default()
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).ready().unwrap();
        assert!(c.error.is_some());
        // no spurious second completion for the same request — and a
        // quiet-but-alive coordinator reports TimedOut, not Disconnected
        assert!(matches!(
            coord.next_completion(Duration::from_millis(300)),
            CompletionWait::TimedOut
        ));
        coord.stop();
    }

    #[test]
    fn batched_and_sequential_rounds_serve_identical_tokens() {
        let mut answers: Vec<Vec<(u64, Vec<u32>)>> = Vec::new();
        for batched in [true, false] {
            let engine = tiny_engine();
            let mut coord = Coordinator::start(
                engine,
                BatcherConfig {
                    max_batch: 4,
                    max_queue: 16,
                    batched,
                    ..BatcherConfig::default()
                },
            );
            for i in 0..6u64 {
                coord
                    .submit(Request {
                        id: i,
                        prompt: (0..2 + i as usize % 3).map(|j| (3 + i as u32 + j as u32) % 32).collect(),
                        max_new: 3 + i as usize % 4,
                        ..Default::default()
                    })
                    .unwrap();
            }
            let mut done = Vec::new();
            for _ in 0..6 {
                let c = coord
                    .next_completion(Duration::from_secs(30))
                    .ready()
                    .expect("completion");
                assert!(c.error.is_none(), "{:?}", c.error);
                done.push((c.id, c.tokens));
            }
            done.sort_by_key(|(id, _)| *id);
            coord.stop();
            answers.push(done);
        }
        assert_eq!(
            answers[0], answers[1],
            "batched and sequential decode rounds must serve bit-identical greedy streams"
        );
    }

    #[test]
    fn duplicate_live_id_is_rejected_with_error_completion() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 1,
                max_queue: 8,
                ..BatcherConfig::default()
            },
        );
        // same id twice while the first is still live
        for _ in 0..2 {
            coord
                .submit(Request {
                    id: 42,
                    prompt: vec![1, 2, 3],
                    max_new: 6,
                    ..Default::default()
                })
                .unwrap();
        }
        // both submissions must be answered — served, or rejected as a
        // duplicate — and the scheduler must survive (no unwrap panic on
        // the shared id in the batched round)
        let mut oks = 0;
        for _ in 0..2 {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            assert_eq!(c.id, 42);
            if c.error.is_none() {
                assert_eq!(c.tokens.len(), 6);
                oks += 1;
            }
        }
        assert!(oks >= 1, "at least one of the duplicates must be served");
        // scheduler still alive and serving
        coord
            .submit(Request {
                id: 7,
                prompt: vec![4, 5],
                max_new: 2,
                ..Default::default()
            })
            .unwrap();
        let c = coord
            .next_completion(Duration::from_secs(30))
            .ready()
            .expect("completion");
        assert_eq!((c.id, c.error), (7, None));
        coord.stop();
    }

    /// A pool sized for ~2 concurrent sessions must still serve an
    /// 8-request load: admission defers (FIFO) until retiring sessions
    /// free pages, and every request completes without error.
    #[test]
    fn pool_constrained_serving_completes_all_requests() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 8,
            // each session: 3-token prompt + 5 decodes = 8 positions = 1
            // page; cap at 2 pages so at most 2 sessions hold KV at once
            pool_pages: Some(2),
            prefix_cache: true,
        });
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 4, // batcher would admit 4; the pool says 2
                max_queue: 16,
                ..BatcherConfig::default()
            },
        );
        let n = 8u64;
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 5,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut done = std::collections::HashSet::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            assert!(c.error.is_none(), "request {}: {:?}", c.id, c.error);
            assert_eq!(c.tokens.len(), 5);
            assert!(done.insert(c.id));
        }
        assert_eq!(done.len() as u64, n);
        // the pool high-water mark is visible in the round summary
        let s = coord.metrics_summary();
        assert!(s.contains("peak 2"), "{s}");
        coord.stop();
    }

    /// A prompt that could never fit the pool is refused at admission
    /// with a clean error completion (the coordinator's error-isolation
    /// path), and the scheduler keeps serving everyone else.
    #[test]
    fn impossible_prompt_refused_with_pool_error() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 4,
            pool_pages: Some(2), // 8 positions total
            prefix_cache: true,
        });
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1; 10], // needs 3 pages for prompt+1 > cap 2
                max_new: 4,
                ..Default::default()
            })
            .unwrap();
        coord
            .submit(Request {
                id: 1,
                prompt: vec![1, 2], // fits
                max_new: 2,
                ..Default::default()
            })
            .unwrap();
        let mut errors = 0;
        let mut served = 0;
        for _ in 0..2 {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            match (c.id, c.error) {
                (0, Some(e)) => {
                    assert!(e.contains("KV pages"), "{e}");
                    errors += 1;
                }
                (1, None) => {
                    assert_eq!(c.tokens.len(), 2);
                    served += 1;
                }
                other => panic!("unexpected completion {other:?}"),
            }
        }
        assert_eq!((errors, served), (1, 1));
        assert!(coord.metrics_summary().contains("kv_refused=1"));
        coord.stop();
    }

    /// Sharing-aware admission: a follower whose prompt extends a live
    /// donor's registered prefix charges only its unshared tail. The
    /// pool is sized so the follower's *full* cost never fits while the
    /// donor is resident — a hit recorded in the prefix stats therefore
    /// proves the tail-only charge admitted it (had admission waited for
    /// the donor to retire, the donor's pages — and their index entries —
    /// would already be gone, and the follower's attach would miss).
    #[test]
    fn cache_hit_prompt_charges_only_its_tail() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 4,
            // donor: 8-token prompt + 8 decodes = 16 positions = 4 pages;
            // follower shares the donor's 2 prompt pages and needs 1
            // private tail page → 5 pages peak. At the follower's full
            // cost of 3 pages, available (at most 2 while the donor
            // lives) never suffices.
            pool_pages: Some(5),
            prefix_cache: true,
        });
        let pool = engine.kv_pool().clone();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        let prefix: Vec<u32> = (0..8).map(|i| (i * 3 + 1) % 32).collect();
        coord
            .submit(Request {
                id: 0,
                prompt: prefix.clone(),
                max_new: 8, // keeps the donor alive for many sweeps
                ..Default::default()
            })
            .unwrap();
        let mut follower = prefix.clone();
        follower.push(29);
        coord
            .submit(Request {
                id: 1,
                prompt: follower,
                max_new: 2,
                ..Default::default()
            })
            .unwrap();
        for _ in 0..2 {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            assert!(c.error.is_none(), "request {}: {:?}", c.id, c.error);
        }
        let s = coord.metrics_summary();
        // donor's lookup missed the empty index, follower's hit it
        assert!(s.contains("prefix_hits=1/2"), "{s}");
        assert!(s.contains("prefix_pages_shared=2"), "{s}");
        coord.stop();
        let stats = pool.prefix_stats();
        assert_eq!((stats.hits, stats.pages_shared), (1, 2), "{stats:?}");
        assert_eq!(
            (pool.pages_in_use(), pool.logical_pages()),
            (0, 0),
            "pool must drain physically and logically"
        );
    }

    /// Sharing-aware admission fuzz: a stream of sessions over a common
    /// two-page prefix — varied tails, a few exact-prefix prompts — is
    /// pushed through a pool too tight to hold them all at full cost.
    /// Deferred requests re-probe the index on every sweep, so a retry
    /// charges only its *current* unshared tail and never re-charges
    /// pages already resident. The whole mix must complete without
    /// error and drain the pool to zero physical and logical pages.
    #[test]
    fn shared_prefix_admission_fuzz_drains_clean() {
        let engine = tiny_engine_with_kv(KvOptions {
            page: 4,
            pool_pages: Some(8),
            prefix_cache: true,
        });
        let pool = engine.kv_pool().clone();
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 3,
                max_queue: 32,
                ..BatcherConfig::default()
            },
        );
        let prefix: Vec<u32> = (0..8).map(|i| (i * 3 + 1) % 32).collect();
        let n = 10u64;
        for i in 0..n {
            let mut prompt = prefix.clone();
            let tail = (i % 4) as usize; // 0 = exact-prefix (full-hit CoW path)
            prompt.extend((0..tail).map(|j| ((i as usize * 5 + j + 11) % 32) as u32));
            coord
                .submit(Request {
                    id: i,
                    prompt,
                    max_new: 3,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut done = std::collections::HashSet::new();
        for _ in 0..n {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            assert!(c.error.is_none(), "request {}: {:?}", c.id, c.error);
            assert_eq!(c.tokens.len(), 3);
            assert!(done.insert(c.id));
        }
        assert_eq!(done.len() as u64, n);
        coord.stop();
        let stats = pool.prefix_stats();
        assert!(stats.hits >= 1, "shared prefixes must hit the index: {stats:?}");
        assert!(stats.pages_shared >= 2, "{stats:?}");
        assert_eq!(
            (pool.pages_in_use(), pool.logical_pages()),
            (0, 0),
            "pool must drain physically and logically"
        );
    }

    #[test]
    fn stop_drains_queued_requests_into_error_completions() {
        let engine = tiny_engine();
        let n = 12u64;
        let mut coord = Coordinator::start(
            engine,
            BatcherConfig {
                max_batch: 1,
                max_queue: 32,
                ..BatcherConfig::default()
            },
        );
        for i in 0..n {
            coord
                .submit(Request {
                    id: i,
                    prompt: vec![1, 2, 3],
                    max_new: 8,
                    ..Default::default()
                })
                .unwrap();
        }
        // stop immediately: most requests are still queued or in flight
        coord.stop();
        let mut seen = std::collections::HashSet::new();
        loop {
            match coord.next_completion(Duration::from_millis(500)) {
                CompletionWait::Ready(c) => {
                    assert!(seen.insert(c.id), "duplicate completion for {}", c.id);
                    if c.error.is_some() {
                        // drained requests carry the shutdown error
                        assert!(c.tokens.len() < 8);
                    }
                }
                // a stopped coordinator's stream ends with Disconnected,
                // never a silent timeout
                CompletionWait::Disconnected => break,
                CompletionWait::TimedOut => panic!("stream must end with Disconnected after stop"),
            }
        }
        assert_eq!(
            seen.len() as u64,
            n,
            "every submitted request must receive exactly one completion"
        );
    }

    /// Satellite: the round-retry backoff schedule is a pure function of
    /// the fault spec (and replica salt) — two schedulers armed with the
    /// same plan draw bit-identical jitter, so a chaos run's retry timing
    /// replays exactly from `BLAST_CHAOS_SEED`. Also pins the schedule's
    /// shape: exponential base doubling up to attempt 4, jitter < 200µs.
    #[test]
    fn retry_backoff_schedule_replays_from_fault_spec() {
        let spec = "decode_round_error:0.4:23";
        let schedule = |f: &Faults| -> Vec<u64> {
            let mut rng = f.fork_rng("round_retry");
            (1..=6).map(|a| retry_backoff_us(a, &mut rng)).collect()
        };
        let a = schedule(&Faults::parse(spec).unwrap());
        let b = schedule(&Faults::parse(spec).unwrap());
        assert_eq!(a, b, "same spec must yield the same retry schedule");
        let c = schedule(&Faults::parse("decode_round_error:0.4:24").unwrap());
        assert_ne!(a, c, "different seeds must jitter differently");
        // per-replica forks of one plan draw distinct (but deterministic)
        // schedules — replicas must not retry in lockstep
        let r1 = schedule(&Faults::parse(spec).unwrap().fork(1));
        let r2 = schedule(&Faults::parse(spec).unwrap().fork(2));
        assert_ne!(r1, r2);
        assert_eq!(r1, schedule(&Faults::parse(spec).unwrap().fork(1)));
        // shape: base 100µs << min(attempt,4) plus sub-200µs jitter
        for (i, &us) in a.iter().enumerate() {
            let base = 100u64 << (i as u64 + 1).min(4);
            assert!(us >= base && us < base + 200, "attempt {}: {us}µs", i + 1);
        }
        // the disabled plan also has a fixed schedule (parity across runs)
        assert_eq!(schedule(&Faults::disabled()), schedule(&Faults::disabled()));
    }

    /// The heartbeat counter advances while the scheduler runs and freezes
    /// after stop; an armed heartbeat_drop plan suppresses bumps without
    /// affecting service.
    #[test]
    fn heartbeat_advances_while_scheduler_lives() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1, 2],
                max_new: 3,
                ..Default::default()
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).ready().unwrap();
        assert!(c.error.is_none());
        // the loop has run at least once per round; the counter moved
        assert!(coord.heartbeat() > 0);
        coord.stop();
        let frozen = coord.heartbeat();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(coord.heartbeat(), frozen, "a stopped scheduler's heartbeat is frozen");

        // with heartbeat_drop always firing, the counter never advances —
        // but requests still complete (the drop is observational only)
        let engine = tiny_engine();
        let mut coord = Coordinator::start_with_faults(
            engine,
            BatcherConfig::default(),
            Faults::parse("heartbeat_drop:1:5").unwrap(),
        );
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1, 2],
                max_new: 2,
                ..Default::default()
            })
            .unwrap();
        let c = coord.next_completion(Duration::from_secs(30)).ready().unwrap();
        assert!(c.error.is_none());
        assert_eq!(coord.heartbeat(), 0, "every bump was dropped");
        coord.stop();
    }

    /// A request whose deadline already passed while it sat in the queue
    /// is expired with a deadline error; a generous deadline is met.
    #[test]
    fn queued_past_deadline_expires_with_error() {
        let engine = tiny_engine();
        let mut coord = Coordinator::start(engine, BatcherConfig::default());
        coord
            .submit(Request {
                id: 0,
                prompt: vec![1, 2],
                max_new: 3,
                deadline_ms: Some(0), // already expired at admission sweep
                ..Default::default()
            })
            .unwrap();
        coord
            .submit(Request {
                id: 1,
                prompt: vec![1, 2],
                max_new: 3,
                deadline_ms: Some(60_000), // easily met
                ..Default::default()
            })
            .unwrap();
        let mut expired = 0;
        let mut served = 0;
        for _ in 0..2 {
            let c = coord
                .next_completion(Duration::from_secs(30))
                .ready()
                .expect("completion");
            match (c.id, &c.error) {
                (0, Some(e)) => {
                    assert!(e.contains("deadline"), "{e}");
                    expired += 1;
                }
                (1, None) => {
                    assert_eq!(c.tokens.len(), 3);
                    served += 1;
                }
                other => panic!("unexpected completion {other:?}"),
            }
        }
        assert_eq!((expired, served), (1, 1));
        assert!(coord.metrics_summary().contains("deadline_misses=1"));
        coord.stop();
    }
}
