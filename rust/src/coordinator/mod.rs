//! Inference serving coordinator (L3).
//!
//! The vLLM-router-shaped component: a bounded admission queue, a
//! continuous batcher that multiplexes decode rounds across active
//! sequences, per-request KV sessions over the shared block-sparse
//! [`crate::model::Engine`], and latency/throughput metrics. All pure
//! scheduling logic lives in [`router`] (deterministically unit- and
//! property-tested); [`server`] adds the threads.
//!
//! By default every decode round is **batched**: the scheduler stacks all
//! prefilled sessions into one `Engine::decode_batch` call, so the MR×NR
//! register tiles of the packed kernels see a real `(B × d_model)` batch
//! dimension instead of degenerate 1-row GEMVs ([`BatcherConfig::batched`]
//! flips back to the sequential baseline; greedy outputs are bit-identical
//! either way). [`metrics`] tracks per-round batch occupancy, tokens/s and
//! KV page-pool residency alongside the request-level latency
//! distributions.
//!
//! Sessions are admitted **against KV pool capacity**: a request is
//! granted a slot only when the engine's [`crate::model::KvPagePool`] has
//! enough free pages for its prompt plus one decode step; otherwise it
//! waits (FIFO — later requests don't jump a deferred head), and a prompt
//! that could never fit the pool at all is answered with an error
//! completion immediately.
//!
//! Above the single coordinator sits the replicated tier (L4): [`replica`]
//! wraps one engine fork + scheduler as a supervised [`replica::Replica`],
//! and [`fleet`] routes sessions across N of them with deterministic
//! placement, heartbeat-based crash/stall detection, bitwise-identical
//! in-flight failover, jittered restarts and graceful drains.

pub mod fleet;
pub mod metrics;
pub mod replica;
pub mod router;
pub mod server;

pub use fleet::{
    Fleet, FleetConfig, FleetMetrics, PlacedEvent, Placer, ReplicaStatus, ReplicaView,
};
pub use metrics::ServeMetrics;
pub use replica::Replica;
pub use router::{Admit, Batcher, BatcherConfig, Request, Session};
pub use server::{Completion, CompletionWait, Coordinator, HealthState};
