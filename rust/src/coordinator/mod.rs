//! Inference serving coordinator (L3).
//!
//! The vLLM-router-shaped component: a bounded admission queue, a
//! continuous batcher that multiplexes decode rounds across active
//! sequences, per-request KV sessions over the shared block-sparse
//! [`crate::model::Engine`], and latency/throughput metrics. All pure
//! scheduling logic lives in [`router`] (deterministically unit- and
//! property-tested); [`server`] adds the threads.

pub mod metrics;
pub mod router;
pub mod server;

pub use metrics::ServeMetrics;
pub use router::{Batcher, BatcherConfig, Request, Session};
pub use server::{Completion, Coordinator};
