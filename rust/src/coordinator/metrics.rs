//! Serving metrics: TTFT / end-to-end latency distributions, decode
//! throughput, queueing stats — the observables behind the Fig. 6
//! end-to-end reproduction.

use std::time::Instant;

use crate::util::stats::{percentile, Welford};

#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    ttft: Welford,
    e2e: Welford,
    queue_wait: Welford,
    ttft_samples: Vec<f64>,
    e2e_samples: Vec<f64>,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub requests_done: u64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            ttft: Welford::new(),
            e2e: Welford::new(),
            queue_wait: Welford::new(),
            ttft_samples: Vec::new(),
            e2e_samples: Vec::new(),
            tokens_generated: 0,
            prefill_tokens: 0,
            requests_done: 0,
        }
    }

    pub fn record_request(
        &mut self,
        queue_secs: f64,
        ttft_secs: f64,
        e2e_secs: f64,
        prompt_tokens: usize,
        new_tokens: usize,
    ) {
        self.queue_wait.push(queue_secs);
        self.ttft.push(ttft_secs);
        self.e2e.push(e2e_secs);
        self.ttft_samples.push(ttft_secs);
        self.e2e_samples.push(e2e_secs);
        self.prefill_tokens += prompt_tokens as u64;
        self.tokens_generated += new_tokens as u64;
        self.requests_done += 1;
    }

    /// Decode throughput since startup (tokens/s).
    pub fn throughput(&self) -> f64 {
        self.tokens_generated as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn ttft_mean(&self) -> f64 {
        self.ttft.mean()
    }

    pub fn e2e_p50(&self) -> f64 {
        percentile(&self.e2e_samples, 50.0)
    }

    pub fn e2e_p99(&self) -> f64 {
        percentile(&self.e2e_samples, 99.0)
    }

    pub fn queue_wait_mean(&self) -> f64 {
        self.queue_wait.mean()
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s ttft_mean={:.1}ms e2e_p50={:.1}ms e2e_p99={:.1}ms",
            self.requests_done,
            self.tokens_generated,
            self.throughput(),
            self.ttft_mean() * 1e3,
            self.e2e_p50() * 1e3,
            self.e2e_p99() * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = ServeMetrics::new();
        for i in 0..10 {
            m.record_request(0.001, 0.01 + i as f64 * 0.001, 0.1, 8, 16);
        }
        assert_eq!(m.requests_done, 10);
        assert_eq!(m.tokens_generated, 160);
        assert!(m.e2e_p50() > 0.0);
        assert!(m.e2e_p99() >= m.e2e_p50());
        assert!(m.summary().contains("requests=10"));
    }
}
