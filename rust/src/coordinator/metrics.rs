//! Serving metrics: TTFT / end-to-end latency distributions, decode
//! throughput, queueing stats, and per-round continuous-batching
//! observables (batch occupancy, tokens/s per round) — the numbers behind
//! the Fig. 6 end-to-end reproduction and the batched-decode A/B.

use std::time::Instant;

use crate::model::engine::AttnStats;
use crate::model::kv::PrefixStats;
use crate::util::stats::{percentile, Welford};

/// Aggregated serving observables; one instance lives behind the
/// coordinator's mutex and is updated by the scheduler thread.
#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    ttft: Welford,
    e2e: Welford,
    queue_wait: Welford,
    ttft_samples: Vec<f64>,
    e2e_samples: Vec<f64>,
    round_batch: Welford,
    round_tok_rate: Welford,
    /// Generated tokens across completed requests (the first of which is
    /// produced by the prefill pass, the rest by decode rounds).
    pub tokens_generated: u64,
    /// Prompt tokens consumed by prefill.
    pub prefill_tokens: u64,
    /// Requests completed (successfully or not).
    pub requests_done: u64,
    /// Decode rounds executed (each touches every active session once).
    pub rounds: u64,
    /// Batched rounds that errored and fell back to sequential decode —
    /// should stay 0; a nonzero value means batching is silently off
    /// (or the KV pool ran dry mid-stream and a session is retiring).
    pub batched_fallbacks: u64,
    /// Requests refused at admission because their prompt could never fit
    /// the KV page pool (answered with error completions).
    pub kv_refused: u64,
    /// Injected faults observed by the scheduler (fault-injection runs
    /// only; 0 in production).
    pub faults_injected: u64,
    /// Transient batched-round failures answered with a retry (bounded,
    /// jittered backoff) rather than a sequential fallback.
    pub round_retries: u64,
    /// Batched decode rounds that panicked and were isolated by
    /// `catch_unwind` (the round fell back to per-session decode).
    pub round_panics: u64,
    /// Individual sessions that panicked during sequential decode and were
    /// retired with an error completion.
    pub session_panics: u64,
    /// Requests that exceeded their deadline — expired in the queue or
    /// retired mid-stream with partial output and a deadline error.
    pub deadline_misses: u64,
    /// Requests shed at admission while the coordinator was Degraded.
    pub shed: u64,
    /// Scheduler-thread deaths caught by the watchdog (pending requests
    /// were failed instead of hanging their clients).
    pub watchdog_trips: u64,
    /// KV pages held by live sessions, as of the last recorded round.
    pub kv_pages_in_use: usize,
    /// Peak concurrent KV pages since startup — the capacity-planning
    /// number the round summaries surface.
    pub kv_pages_peak: usize,
    /// Resident KV bytes across live sessions, as of the last recorded
    /// round (actual pages held, not the `max_seq` preallocation bound).
    pub kv_resident_bytes: usize,
    /// Prefix-index lookups (prefills with at least one full prompt page,
    /// prefix cache on). 0 means sharing never engaged — the prefix
    /// fields stay out of the summary so sharing-off output is
    /// byte-identical to the unshared coordinator.
    pub prefix_lookups: u64,
    /// Prefix lookups that mapped at least one shared KV page.
    pub prefix_hits: u64,
    /// KV pages mapped from the prefix index instead of recomputed
    /// (cumulative).
    pub prefix_pages_shared: u64,
    /// Copy-on-write page copies (cumulative).
    pub cow_copies: u64,
    /// Logical page mappings across live sessions (each shared page
    /// counts once per session), as of the last recorded round.
    pub kv_logical_pages: usize,
    /// Peak *effective* pool capacity in pages: the physical capacity
    /// multiplied by the logical/physical sharing ratio at its best
    /// observed moment — what the pool would have needed without sharing.
    pub kv_effective_capacity: f64,
    /// BLASST attention skip counters, mirrored from the engine's
    /// cumulative [`AttnStats`] snapshot. All-zero on an exact engine
    /// (threshold off), so the summary stays byte-identical to the
    /// pre-threshold coordinator unless the knob is armed.
    pub attn: AttnStats,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    /// Fresh metrics; the throughput clock starts now.
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            ttft: Welford::new(),
            e2e: Welford::new(),
            queue_wait: Welford::new(),
            ttft_samples: Vec::new(),
            e2e_samples: Vec::new(),
            round_batch: Welford::new(),
            round_tok_rate: Welford::new(),
            tokens_generated: 0,
            prefill_tokens: 0,
            requests_done: 0,
            rounds: 0,
            batched_fallbacks: 0,
            kv_refused: 0,
            faults_injected: 0,
            round_retries: 0,
            round_panics: 0,
            session_panics: 0,
            deadline_misses: 0,
            shed: 0,
            watchdog_trips: 0,
            kv_pages_in_use: 0,
            kv_pages_peak: 0,
            kv_resident_bytes: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_pages_shared: 0,
            cow_copies: 0,
            kv_logical_pages: 0,
            kv_effective_capacity: 0.0,
            attn: AttnStats::default(),
        }
    }

    /// Record one completed request (latencies in seconds).
    pub fn record_request(
        &mut self,
        queue_secs: f64,
        ttft_secs: f64,
        e2e_secs: f64,
        prompt_tokens: usize,
        new_tokens: usize,
    ) {
        self.queue_wait.push(queue_secs);
        self.ttft.push(ttft_secs);
        self.e2e.push(e2e_secs);
        self.ttft_samples.push(ttft_secs);
        self.e2e_samples.push(e2e_secs);
        self.prefill_tokens += prompt_tokens as u64;
        self.tokens_generated += new_tokens as u64;
        self.requests_done += 1;
    }

    /// Record one continuous-batching decode round: how many sessions took
    /// a step, how long the round took, and how many tokens it produced.
    /// Mean batch size is the occupancy of the `(B × d_model)` GEMMs; the
    /// per-round token rate is the quantity the batched-vs-sequential A/B
    /// (`blast exp serve`) gates on.
    pub fn record_round(&mut self, batch_size: usize, secs: f64, new_tokens: usize) {
        self.rounds += 1;
        self.round_batch.push(batch_size as f64);
        if secs > 0.0 {
            self.round_tok_rate.push(new_tokens as f64 / secs);
        }
    }

    /// Record the KV page pool's state as observed after a round (or a
    /// prefill batch): pages held by live sessions, the pool's own
    /// high-water mark (the pool is the single source of truth for the
    /// peak — pages allocated outside the scheduler loop count too), and
    /// resident bytes.
    pub fn record_kv(&mut self, pages_in_use: usize, pages_peak: usize, resident_bytes: usize) {
        self.kv_pages_in_use = pages_in_use;
        self.kv_pages_peak = self.kv_pages_peak.max(pages_peak).max(pages_in_use);
        self.kv_resident_bytes = resident_bytes;
    }

    /// Mirror the pool's prefix-sharing counters (see
    /// [`crate::model::kv::KvPagePool::prefix_stats`]) and fold the
    /// current sharing ratio into the peak effective capacity:
    /// `capacity × logical/physical` pages (an unbounded pool uses its
    /// physical residency as the base). With the prefix cache off every
    /// counter stays 0 and the summary is unchanged.
    pub fn record_prefix(&mut self, stats: &PrefixStats, capacity_pages: Option<usize>) {
        self.prefix_lookups = stats.lookups;
        self.prefix_hits = stats.hits;
        self.prefix_pages_shared = stats.pages_shared;
        self.cow_copies = stats.cow_copies;
        self.kv_logical_pages = stats.logical_pages;
        let ratio = if stats.physical_pages > 0 {
            stats.logical_pages as f64 / stats.physical_pages as f64
        } else {
            1.0
        };
        let base = capacity_pages.unwrap_or(stats.physical_pages) as f64;
        self.kv_effective_capacity = self.kv_effective_capacity.max(base * ratio);
    }

    /// Mirror the engine's cumulative BLASST skip counters. The engine
    /// snapshot is already cumulative, so this replaces rather than
    /// accumulates; an exact engine reports all zeros and the summary
    /// stays unchanged.
    pub fn record_attn(&mut self, stats: AttnStats) {
        self.attn = stats;
    }

    /// Decode throughput since startup (tokens/s).
    pub fn throughput(&self) -> f64 {
        self.tokens_generated as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Mean time-to-first-token (seconds).
    pub fn ttft_mean(&self) -> f64 {
        self.ttft.mean()
    }

    /// Median end-to-end request latency (seconds).
    pub fn e2e_p50(&self) -> f64 {
        percentile(&self.e2e_samples, 50.0)
    }

    /// 99th-percentile end-to-end request latency (seconds).
    pub fn e2e_p99(&self) -> f64 {
        percentile(&self.e2e_samples, 99.0)
    }

    /// Mean time spent in the admission queue (seconds).
    pub fn queue_wait_mean(&self) -> f64 {
        self.queue_wait.mean()
    }

    /// Mean sessions per decode round (continuous-batch occupancy).
    pub fn mean_round_batch(&self) -> f64 {
        self.round_batch.mean()
    }

    /// Mean per-round decode rate (tokens/s measured within rounds, i.e.
    /// excluding prefill and scheduling gaps).
    pub fn round_tokens_per_s(&self) -> f64 {
        self.round_tok_rate.mean()
    }

    /// One-line human-readable digest of everything above, including the
    /// KV pool residency + high-water mark (the fallback / refusal
    /// counters appear only when nonzero — they should never be).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} throughput={:.1} tok/s ttft_mean={:.1}ms e2e_p50={:.1}ms e2e_p99={:.1}ms rounds={} mean_batch={:.2} round_tok/s={:.1} kv_pages={} (peak {}) kv_resident={:.1}KiB",
            self.requests_done,
            self.tokens_generated,
            self.throughput(),
            self.ttft_mean() * 1e3,
            self.e2e_p50() * 1e3,
            self.e2e_p99() * 1e3,
            self.rounds,
            self.mean_round_batch(),
            self.round_tokens_per_s(),
            self.kv_pages_in_use,
            self.kv_pages_peak,
            self.kv_resident_bytes as f64 / 1024.0,
        );
        for (name, v) in [
            ("batched_fallbacks", self.batched_fallbacks),
            ("kv_refused", self.kv_refused),
            ("faults_injected", self.faults_injected),
            ("round_retries", self.round_retries),
            ("round_panics", self.round_panics),
            ("session_panics", self.session_panics),
            ("deadline_misses", self.deadline_misses),
            ("shed", self.shed),
            ("watchdog_trips", self.watchdog_trips),
        ] {
            if v > 0 {
                s.push_str(&format!(" {name}={v}"));
            }
        }
        // prefix-sharing digest appears only once the index has been
        // consulted, so a sharing-off (or never-sharing) run's summary is
        // byte-identical to the unshared coordinator's
        if self.prefix_lookups > 0 {
            s.push_str(&format!(
                " prefix_hits={}/{} prefix_pages_shared={} cow_copies={} effective_capacity={:.1}",
                self.prefix_hits,
                self.prefix_lookups,
                self.prefix_pages_shared,
                self.cow_copies,
                self.kv_effective_capacity,
            ));
        }
        // attention-skip digest appears only when a threshold-armed
        // kernel has actually run (exact engines never count), keeping
        // τ=off summaries byte-identical to the pre-threshold output
        if self.attn.engaged() {
            s.push_str(&format!(
                " attn_rows_skipped={}/{} attn_tiles_skipped={}/{} attn_pages_skipped={}/{} attn_row_skip={:.1}%",
                self.attn.rows_skipped,
                self.attn.rows,
                self.attn.tiles_skipped,
                self.attn.tiles,
                self.attn.pages_skipped,
                self.attn.pages,
                self.attn.row_skip_frac() * 100.0,
            ));
        }
        s
    }

    /// The **timing-independent** counters only — the subset two runs of
    /// the same deterministic workload must agree on byte-for-byte. The
    /// full [`ServeMetrics::summary`] includes wall-clock-derived fields
    /// (throughput, latency percentiles, round structure) that legitimately
    /// differ across runs; equivalence tests (fleet `--replicas 1` vs the
    /// single coordinator) compare this digest instead.
    pub fn invariant_digest(&self) -> String {
        format!(
            "requests={} tokens={} prefill_tokens={} kv_refused={} deadline_misses={} shed={} watchdog_trips={}",
            self.requests_done,
            self.tokens_generated,
            self.prefill_tokens,
            self.kv_refused,
            self.deadline_misses,
            self.shed,
            self.watchdog_trips,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = ServeMetrics::new();
        for i in 0..10 {
            m.record_request(0.001, 0.01 + i as f64 * 0.001, 0.1, 8, 16);
        }
        assert_eq!(m.requests_done, 10);
        assert_eq!(m.tokens_generated, 160);
        assert!(m.e2e_p50() > 0.0);
        assert!(m.e2e_p99() >= m.e2e_p50());
        assert!(m.summary().contains("requests=10"));
    }

    #[test]
    fn round_stats_track_occupancy_and_rate() {
        let mut m = ServeMetrics::new();
        m.record_round(4, 0.010, 4);
        m.record_round(2, 0.005, 2);
        m.record_round(0, 0.0, 0); // zero-duration round must not divide by 0
        assert_eq!(m.rounds, 3);
        assert!((m.mean_round_batch() - 2.0).abs() < 1e-9);
        assert!((m.round_tokens_per_s() - 400.0).abs() < 1e-6);
        assert!(m.summary().contains("rounds=3"));
    }

    #[test]
    fn kv_gauges_track_current_and_peak() {
        let mut m = ServeMetrics::new();
        m.record_kv(5, 5, 5 * 4096);
        m.record_kv(9, 9, 9 * 4096);
        // current drops; the pool-reported peak sticks
        m.record_kv(2, 9, 2 * 4096);
        assert_eq!(m.kv_pages_in_use, 2);
        assert_eq!(m.kv_pages_peak, 9);
        assert_eq!(m.kv_resident_bytes, 2 * 4096);
        let s = m.summary();
        assert!(s.contains("kv_pages=2 (peak 9)"), "{s}");
        assert!(s.contains("kv_resident=8.0KiB"), "{s}");
        // refusal counter only appears when nonzero
        assert!(!s.contains("kv_refused"));
        m.kv_refused = 3;
        assert!(m.summary().contains("kv_refused=3"));
    }

    #[test]
    fn prefix_fields_appear_only_after_a_lookup() {
        let mut m = ServeMetrics::new();
        // no lookups → summary byte-identical to the unshared path
        assert!(!m.summary().contains("prefix_hits"), "{}", m.summary());
        assert!(!m.summary().contains("effective_capacity"), "{}", m.summary());
        // sharing-off pools report all-zero stats; recording them must
        // keep the summary clean
        m.record_prefix(&PrefixStats::default(), Some(64));
        assert!(!m.summary().contains("prefix_hits"), "{}", m.summary());
        // 12 logical mappings on 4 physical pages = 3× multiplier over a
        // 64-page pool
        let stats = PrefixStats {
            lookups: 5,
            hits: 4,
            pages_shared: 9,
            cow_copies: 2,
            logical_pages: 12,
            physical_pages: 4,
        };
        m.record_prefix(&stats, Some(64));
        assert_eq!(m.prefix_hits, 4);
        assert_eq!(m.kv_logical_pages, 12);
        assert!((m.kv_effective_capacity - 192.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("prefix_hits=4/5"), "{s}");
        assert!(s.contains("prefix_pages_shared=9"), "{s}");
        assert!(s.contains("cow_copies=2"), "{s}");
        assert!(s.contains("effective_capacity=192.0"), "{s}");
        // the effective-capacity peak sticks when sharing later drops
        m.record_prefix(&PrefixStats { lookups: 6, logical_pages: 2, physical_pages: 2, ..stats }, Some(64));
        assert!((m.kv_effective_capacity - 192.0).abs() < 1e-9);
        // unbounded pools fall back to physical residency as the base
        let mut u = ServeMetrics::new();
        u.record_prefix(&PrefixStats { lookups: 1, hits: 1, pages_shared: 2, cow_copies: 0, logical_pages: 6, physical_pages: 3 }, None);
        assert!((u.kv_effective_capacity - 6.0).abs() < 1e-9);
    }

    #[test]
    fn fault_counters_appear_only_when_nonzero() {
        let mut m = ServeMetrics::new();
        let clean = m.summary();
        for name in [
            "faults_injected",
            "round_retries",
            "round_panics",
            "session_panics",
            "deadline_misses",
            "shed",
            "watchdog_trips",
        ] {
            assert!(!clean.contains(name), "{clean}");
        }
        m.round_panics = 2;
        m.deadline_misses = 1;
        m.shed = 4;
        m.watchdog_trips = 1;
        let s = m.summary();
        for want in ["round_panics=2", "deadline_misses=1", "shed=4", "watchdog_trips=1"] {
            assert!(s.contains(want), "{s}");
        }
    }

    #[test]
    fn attn_fields_appear_only_when_engaged() {
        let mut m = ServeMetrics::new();
        assert!(!m.summary().contains("attn_"), "{}", m.summary());
        // an exact engine records all-zero snapshots; summary stays clean
        m.record_attn(AttnStats::default());
        assert!(!m.summary().contains("attn_"), "{}", m.summary());
        // armed engine: cumulative snapshot replaces, not accumulates
        m.record_attn(AttnStats {
            tiles: 10,
            tiles_skipped: 2,
            rows: 80,
            rows_skipped: 20,
            pages: 6,
            pages_skipped: 1,
        });
        m.record_attn(AttnStats {
            tiles: 12,
            tiles_skipped: 3,
            rows: 100,
            rows_skipped: 25,
            pages: 8,
            pages_skipped: 2,
        });
        assert_eq!(m.attn.rows, 100);
        let s = m.summary();
        assert!(s.contains("attn_rows_skipped=25/100"), "{s}");
        assert!(s.contains("attn_tiles_skipped=3/12"), "{s}");
        assert!(s.contains("attn_pages_skipped=2/8"), "{s}");
        assert!(s.contains("attn_row_skip=25.0%"), "{s}");
    }
}
