//! Replicated serving fleet (L4): a front-end router that owns global
//! admission and places sessions across N supervised [`Replica`]s.
//!
//! The fleet is the outermost failure domain, above the coordinator's
//! round → session → scheduler ladder. Its router thread:
//!
//! * **places** each request on the least-loaded healthy, non-draining
//!   replica ([`Placer`] — a pure function of the fleet seed and arrival
//!   order, with a seeded hash breaking load ties, so placement replays
//!   bit-for-bit and is pinned by `fleet_check.py`);
//! * **detects** crashed replicas (completion channel disconnects after
//!   the watchdog drains) and stalled ones (the scheduler heartbeat stops
//!   advancing past [`FleetConfig::stall_ms`]) and deposes them with a
//!   non-joining stop — a stalled scheduler must never block the router;
//! * **fails over** in-flight sessions: greedy decode is deterministic,
//!   so replaying `prompt ++ already-emitted-tokens` as a fresh prompt on
//!   a survivor (with the decode budget reduced by what was already
//!   emitted) continues the stream **bitwise-identically** — prefill
//!   pushes the argmax as the first output token, i.e. exactly the token
//!   the dead replica would have produced next;
//! * **restarts** dead replicas after a jittered, bounded exponential
//!   backoff ([`restart_backoff_ms`], jitter from the fault-plan-forked
//!   RNG so chaos schedules replay). A replica that exhausts
//!   [`FleetConfig::max_restarts`] is marked Lost and never placed again;
//! * **drains** on request ([`Fleet::drain`]): the replica stops taking
//!   placements, finishes its in-flight sessions, then acks — which is
//!   what makes [`Fleet::rolling_restart`] drop zero requests.
//!
//! Every request submitted to the fleet is answered **exactly once**: by
//! a success, by a terminal error, or — at shutdown — by a synthetic
//! "fleet stopped" error. Duplicated work from a deposed-but-live replica
//! is fenced at the router: a completion whose id is not in that
//! replica's outstanding set is counted stale and dropped.
//!
//! A one-replica fleet is byte-identical to a bare
//! [`Coordinator`]: replica 0's first incarnation forks the fault plan
//! with salt 0 (the root plan), placement is a no-op, and completions
//! pass through verbatim.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::metrics::ServeMetrics;
use crate::coordinator::replica::Replica;
use crate::kernels::attention::AttnStats;
use crate::coordinator::router::{BatcherConfig, Request};
use crate::coordinator::server::{Completion, CompletionWait, Coordinator, HealthState};
use crate::model::engine::Engine;
use crate::model::kv::KvPagePool;
use crate::util::faults::Faults;
use crate::util::rng::Rng;

/// Fleet shape and supervision knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Number of replicas (clamped to >= 1).
    pub replicas: usize,
    /// Per-replica scheduler configuration.
    pub batcher: BatcherConfig,
    /// Placement seed: with the arrival order, fully determines which
    /// replica every session lands on.
    pub seed: u64,
    /// Depose a replica whose scheduler heartbeat has not advanced for
    /// this long. Must sit well above both the idle poll period (20 ms)
    /// and a decode round, and below the latency budget of failover.
    pub stall_ms: u64,
    /// Base of the jittered exponential restart backoff, in milliseconds.
    pub restart_backoff_ms: u64,
    /// A replica restarted this many times is marked Lost for good.
    pub max_restarts: u64,
    /// A request failed over this many times is answered with its last
    /// error instead of being replayed again.
    pub max_failovers: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replicas: 1,
            batcher: BatcherConfig::default(),
            seed: 0,
            stall_ms: 250,
            restart_backoff_ms: 5,
            max_restarts: 8,
            max_failovers: 4,
        }
    }
}

/// What the placer sees of one replica at placement time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReplicaView {
    /// Fixed fleet slot.
    pub id: usize,
    /// Up and reporting [`HealthState::Healthy`] (Degraded replicas shed
    /// at their own admission gate; the fleet routes around them).
    pub healthy: bool,
    /// Draining: finishes in-flight work, receives no new placements.
    pub draining: bool,
    /// Sessions currently outstanding on this replica.
    pub load: usize,
}

/// One placement decision, recorded for the purity oracle: replaying the
/// event's `views` through a fresh [`Placer`] must re-derive `chosen`.
#[derive(Clone, Debug)]
pub struct PlacedEvent {
    /// Arrival index consumed by this decision.
    pub arrival: u64,
    /// Request placed.
    pub id: u64,
    /// Fleet snapshot the decision was made against.
    pub views: Vec<ReplicaView>,
    /// Replica chosen.
    pub chosen: usize,
}

/// splitmix64 finalizer over `(seed, arrival)` — the tie-break hash.
/// Pinned (and transliterated in `fleet_check.py`): do not change.
pub fn placement_mix(seed: u64, arrival: u64) -> u64 {
    let mut z = seed ^ arrival.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Jittered exponential backoff before restart `attempt` (0-based), in
/// milliseconds. Pinned by `fleet_check.py` — the jitter RNG is forked
/// from the fault plan so chaos restart schedules replay bit-for-bit.
pub fn restart_backoff_ms(base: u64, attempt: u64, rng: &mut Rng) -> u64 {
    let base = base.max(1);
    (base << attempt.min(4)) + rng.below(base as usize) as u64
}

/// Pure placement policy: least-loaded among healthy, non-draining
/// replicas, ties broken by [`placement_mix`] over the arrival index.
/// Given the same seed and the same sequence of view snapshots, a
/// `Placer` makes the same decisions — no wall clock, no thread state.
pub struct Placer {
    seed: u64,
    arrivals: u64,
}

impl Placer {
    /// A placer for one fleet lifetime.
    pub fn new(seed: u64) -> Placer {
        Placer { seed, arrivals: 0 }
    }

    /// Arrival indices consumed so far.
    pub fn arrivals(&self) -> u64 {
        self.arrivals
    }

    /// Choose a replica, or `None` when no replica is eligible — in which
    /// case **no arrival index is consumed** (the decision never
    /// happened; the caller requeues and retries later).
    pub fn place(&mut self, views: &[ReplicaView]) -> Option<(u64, usize)> {
        let best = views
            .iter()
            .filter(|v| v.healthy && !v.draining)
            .map(|v| v.load)
            .min()?;
        let ties: Vec<usize> = views
            .iter()
            .filter(|v| v.healthy && !v.draining && v.load == best)
            .map(|v| v.id)
            .collect();
        let arrival = self.arrivals;
        self.arrivals += 1;
        let pick = (placement_mix(self.seed, arrival) % ties.len() as u64) as usize;
        Some((arrival, ties[pick]))
    }
}

/// Router-level counters, aggregated across replicas and incarnations.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    /// Placement decisions that reached a replica's queue.
    pub placed: u64,
    /// Sessions replayed onto a survivor after their replica failed.
    pub failovers: u64,
    /// Replica restarts, crash-driven and planned together.
    pub restarts: u64,
    /// Restarts that were graceful (drain → stop → fresh incarnation).
    pub planned_restarts: u64,
    /// Deposals triggered by a frozen heartbeat.
    pub deposed_stalls: u64,
    /// Deposals triggered by a disconnected completion channel.
    pub replica_deaths: u64,
    /// Drain requests honoured.
    pub drains: u64,
    /// Requests answered with a terminal error.
    pub failed: u64,
    /// Completions fenced off because their replica had been deposed.
    pub stale_completions: u64,
    /// Replicas abandoned after exhausting their restart budget.
    pub replicas_lost: u64,
    /// Placement event log (the purity oracle's input).
    pub events: Vec<PlacedEvent>,
}

impl FleetMetrics {
    /// One-line counter digest (timing-independent).
    pub fn summary(&self) -> String {
        format!(
            "placed={} failovers={} restarts={} (planned {}) stalls={} deaths={} \
             drains={} lost={} failed={} stale={}",
            self.placed,
            self.failovers,
            self.restarts,
            self.planned_restarts,
            self.deposed_stalls,
            self.replica_deaths,
            self.drains,
            self.replicas_lost,
            self.failed,
            self.stale_completions,
        )
    }
}

/// Externally visible state of one replica slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaStatus {
    /// Up and taking placements.
    Healthy,
    /// Up but shedding at its own admission gate; not placed on.
    Degraded,
    /// Finishing in-flight work; not placed on.
    Draining,
    /// Deposed, waiting out its restart backoff.
    Down,
    /// Restart budget exhausted; never coming back.
    Lost,
}

enum FleetMsg {
    Submit(Request),
    Drain(usize, Sender<()>),
    Restart(usize, Sender<()>),
    Stop,
}

/// Handle to a running fleet: submit requests, receive completions,
/// drain/restart replicas, read metrics. Mirrors the [`Coordinator`]
/// surface so `--replicas 1` is a drop-in.
pub struct Fleet {
    cmd_tx: Option<Sender<FleetMsg>>,
    done_rx: Receiver<Completion>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<FleetMetrics>>,
    statuses: Arc<Mutex<Vec<ReplicaStatus>>>,
    serve_handles: Arc<Mutex<Vec<Arc<Mutex<ServeMetrics>>>>>,
    pools: Arc<Mutex<Vec<Arc<KvPagePool>>>>,
    replicas: usize,
}

fn flock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Fleet {
    /// Start a fleet over forks of `base` (no fault injection).
    pub fn start(base: &Engine, cfg: FleetConfig) -> Fleet {
        Fleet::start_with_faults(base, cfg, Faults::disabled())
    }

    /// Start a fleet with a fault plan armed. Each replica incarnation
    /// forks the plan with its own salt, so every scheduler draws
    /// deterministic, independent fault streams.
    pub fn start_with_faults(base: &Engine, cfg: FleetConfig, faults: Faults) -> Fleet {
        let n = cfg.replicas.max(1);
        let mut slots = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let mut pools = Vec::with_capacity(n);
        for id in 0..n {
            let rep = Replica::start(id, base, cfg.batcher, faults.clone());
            handles.push(rep.coord().metrics_arc());
            pools.push(rep.pool());
            slots.push(Slot::new(rep));
        }
        let (cmd_tx, cmd_rx) = mpsc::channel::<FleetMsg>();
        let (done_tx, done_rx) = mpsc::channel::<Completion>();
        let metrics = Arc::new(Mutex::new(FleetMetrics::default()));
        let statuses = Arc::new(Mutex::new(vec![ReplicaStatus::Healthy; n]));
        let serve_handles = Arc::new(Mutex::new(handles));
        let pools = Arc::new(Mutex::new(pools));
        let m2 = metrics.clone();
        let st2 = statuses.clone();
        let h2 = serve_handles.clone();
        let p2 = pools.clone();
        let worker = std::thread::spawn(move || {
            router_loop(slots, cmd_rx, done_tx, cfg, faults, m2, st2, h2, p2);
        });
        Fleet {
            cmd_tx: Some(cmd_tx),
            done_rx,
            worker: Some(worker),
            metrics,
            statuses,
            serve_handles,
            pools,
            replicas: n,
        }
    }

    fn send(&self, msg: FleetMsg) -> Result<()> {
        match &self.cmd_tx {
            Some(tx) => tx.send(msg).map_err(|_| anyhow::anyhow!("fleet stopped")),
            None => anyhow::bail!("fleet stopped"),
        }
    }

    /// Submit a request; the router tracks it until it is answered
    /// exactly once on the completion stream.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.send(FleetMsg::Submit(req))
    }

    /// Wait for the next completion (same semantics as
    /// [`Coordinator::next_completion`]).
    pub fn next_completion(&self, timeout: Duration) -> CompletionWait {
        match self.done_rx.recv_timeout(timeout) {
            Ok(c) => CompletionWait::Ready(c),
            Err(RecvTimeoutError::Timeout) => CompletionWait::TimedOut,
            Err(RecvTimeoutError::Disconnected) => CompletionWait::Disconnected,
        }
    }

    /// Drain replica `r`: stop placing on it, block until its in-flight
    /// sessions have all completed. The replica stays draining (use
    /// [`Fleet::restart_replica`] to cycle it back in).
    pub fn drain(&self, r: usize) -> Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.send(FleetMsg::Drain(r, ack_tx))?;
        ack_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("drain of replica {r} never acknowledged"))
    }

    /// Gracefully cycle replica `r`: drain it, stop its scheduler, bring
    /// up a fresh incarnation, resume placements. Blocks until done; no
    /// request is dropped at any point.
    pub fn restart_replica(&self, r: usize) -> Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.send(FleetMsg::Restart(r, ack_tx))?;
        ack_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("restart of replica {r} never acknowledged"))
    }

    /// Restart every replica in turn — a zero-downtime rolling restart
    /// (each replica drains before it cycles; the rest keep serving).
    pub fn rolling_restart(&self) -> Result<()> {
        for r in 0..self.replicas {
            self.restart_replica(r)?;
        }
        Ok(())
    }

    /// Current status of every replica slot.
    pub fn statuses(&self) -> Vec<ReplicaStatus> {
        flock(&self.statuses).clone()
    }

    /// Snapshot of the router counters and placement event log.
    pub fn metrics(&self) -> FleetMetrics {
        flock(&self.metrics).clone()
    }

    /// Every KV pool the fleet has ever built — one per replica
    /// incarnation. After [`Fleet::stop`] all of them must be fully
    /// drained; the chaos harness asserts exactly that.
    pub fn pools(&self) -> Vec<Arc<KvPagePool>> {
        flock(&self.pools).clone()
    }

    /// Timing-independent per-replica counter digests (current
    /// incarnations, in slot order).
    pub fn replica_digests(&self) -> Vec<String> {
        flock(&self.serve_handles)
            .iter()
            .map(|h| flock(h).invariant_digest())
            .collect()
    }

    /// Human-readable fleet summary. A one-replica fleet that never saw a
    /// fleet-level event reports its replica's serving summary verbatim —
    /// byte-identical to running the bare coordinator.
    pub fn metrics_summary(&self) -> String {
        let fm = flock(&self.metrics).clone();
        let handles = flock(&self.serve_handles).clone();
        let quiet = fm.failovers == 0
            && fm.restarts == 0
            && fm.deposed_stalls == 0
            && fm.replica_deaths == 0
            && fm.drains == 0
            && fm.replicas_lost == 0
            && fm.stale_completions == 0;
        if self.replicas == 1 && quiet {
            return flock(&handles[0]).summary();
        }
        let mut out = format!("fleet replicas={} {}", self.replicas, fm.summary());
        for (r, h) in handles.iter().enumerate() {
            out.push_str(&format!("\n  replica {r}: {}", flock(h).summary()));
        }
        if let Some(attn) = self.attn_aggregate() {
            out.push_str(&format!(
                "\n  fleet attn: rows_skipped={}/{} tiles_skipped={}/{} pages_skipped={}/{}",
                attn.rows_skipped,
                attn.rows,
                attn.tiles_skipped,
                attn.tiles,
                attn.pages_skipped,
                attn.pages,
            ));
        }
        out
    }

    /// BLASST skip counters summed across the current replica
    /// incarnations, or `None` when no replica's threshold ever engaged
    /// (exact fleets keep their summary byte-identical to pre-threshold
    /// output). Counters from deposed incarnations retire with their
    /// `ServeMetrics`, matching every other per-replica observable.
    pub fn attn_aggregate(&self) -> Option<AttnStats> {
        let mut total = AttnStats::default();
        for h in flock(&self.serve_handles).iter() {
            total.merge(&flock(h).attn);
        }
        total.engaged().then_some(total)
    }

    /// Stop the fleet: every replica stops, every tracked request is
    /// answered (with an error if it could not finish), the completion
    /// stream drains then disconnects.
    pub fn stop(&mut self) {
        if let Some(tx) = self.cmd_tx.take() {
            tx.send(FleetMsg::Stop).ok();
        }
        if let Some(h) = self.worker.take() {
            h.join().ok();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Errors worth replaying on a survivor: the replica failed, not the
/// request. Deadline misses, pool-capacity refusals and duplicate ids
/// would fail identically anywhere — those stay terminal.
fn failover_eligible(err: &str) -> bool {
    err.contains("scheduler thread panicked")
        || err.contains("coordinator stopped")
        || err.contains("shedding load")
        || err.contains("session panicked")
        || err.contains("replica deposed")
}

struct Slot {
    rep: Replica,
    outstanding: HashSet<u64>,
    draining: bool,
    drain_acks: Vec<Sender<()>>,
    restart_ack: Option<Sender<()>>,
    hb_last: u64,
    hb_at: Instant,
    down_until: Option<Instant>,
    lost: bool,
}

impl Slot {
    fn new(rep: Replica) -> Slot {
        Slot {
            rep,
            outstanding: HashSet::new(),
            draining: false,
            drain_acks: Vec::new(),
            restart_ack: None,
            hb_last: 0,
            hb_at: Instant::now(),
            down_until: None,
            lost: false,
        }
    }

    fn up(&self) -> bool {
        !self.lost && self.down_until.is_none()
    }

    fn view(&self) -> ReplicaView {
        ReplicaView {
            id: self.rep.id(),
            healthy: self.up() && self.rep.health() == HealthState::Healthy,
            draining: self.draining,
            load: self.outstanding.len(),
        }
    }

    fn status(&self) -> ReplicaStatus {
        if self.lost {
            ReplicaStatus::Lost
        } else if self.down_until.is_some() {
            ReplicaStatus::Down
        } else if self.draining {
            ReplicaStatus::Draining
        } else {
            match self.rep.health() {
                HealthState::Healthy => ReplicaStatus::Healthy,
                HealthState::Degraded => ReplicaStatus::Degraded,
                // the scheduler has exited; the next poll deposes it
                HealthState::Draining => ReplicaStatus::Down,
            }
        }
    }
}

struct Tracked {
    req: Request,
    emitted: Vec<u32>,
    failovers: usize,
    submitted: Instant,
}

/// The replayed request for a failed-over session: original prompt plus
/// everything already emitted, decode budget and deadline reduced by what
/// has already happened. Greedy determinism makes the survivor's first
/// prefill argmax exactly the token the dead replica would have produced.
fn replay_request(t: &Tracked) -> Request {
    let mut prompt = t.req.prompt.clone();
    prompt.extend_from_slice(&t.emitted);
    Request {
        id: t.req.id,
        prompt,
        max_new: t.req.max_new.saturating_sub(t.emitted.len()),
        eos: t.req.eos,
        deadline_ms: t
            .req
            .deadline_ms
            .map(|d| d.saturating_sub(t.submitted.elapsed().as_millis() as u64)),
    }
}

fn error_completion(id: u64, tokens: Vec<u32>, err: String) -> Completion {
    Completion {
        id,
        tokens,
        queue_secs: 0.0,
        ttft_secs: 0.0,
        e2e_secs: 0.0,
        error: Some(err),
    }
}

/// A replica failed a request for a replica-shaped reason: absorb any
/// partial tokens it produced, then either finish the request from what
/// has been emitted (budget or eos already reached), answer terminally
/// (failover budget exhausted), or queue it for replacement.
#[allow(clippy::too_many_arguments)]
fn route_failover(
    id: u64,
    extra: &[u32],
    err: &str,
    tracked: &mut HashMap<u64, Tracked>,
    place_queue: &mut VecDeque<u64>,
    done_tx: &Sender<Completion>,
    metrics: &Mutex<FleetMetrics>,
    max_failovers: usize,
) {
    let Some(t) = tracked.get_mut(&id) else { return };
    t.emitted.extend_from_slice(extra);
    let finished = t.emitted.len() >= t.req.max_new
        || t.req.eos.is_some_and(|e| t.emitted.last() == Some(&e));
    if finished {
        let t = tracked.remove(&id).unwrap();
        done_tx
            .send(Completion {
                id,
                tokens: t.emitted,
                queue_secs: 0.0,
                ttft_secs: 0.0,
                e2e_secs: t.submitted.elapsed().as_secs_f64(),
                error: None,
            })
            .ok();
        return;
    }
    if t.failovers >= max_failovers {
        let t = tracked.remove(&id).unwrap();
        flock(metrics).failed += 1;
        done_tx
            .send(error_completion(
                id,
                t.emitted,
                format!("request {id} exhausted failovers: {err}"),
            ))
            .ok();
        return;
    }
    t.failovers += 1;
    flock(metrics).failovers += 1;
    place_queue.push_back(id);
}

/// Depose one replica: stop it without joining, fail its outstanding
/// sessions over, schedule a backed-off restart.
#[allow(clippy::too_many_arguments)]
fn depose_slot(
    slot: &mut Slot,
    rng: &mut Rng,
    now: Instant,
    cfg: &FleetConfig,
    tracked: &mut HashMap<u64, Tracked>,
    place_queue: &mut VecDeque<u64>,
    done_tx: &Sender<Completion>,
    metrics: &Mutex<FleetMetrics>,
) {
    slot.rep.coord().request_stop();
    let mut ids: Vec<u64> = slot.outstanding.drain().collect();
    ids.sort_unstable();
    for id in ids {
        route_failover(
            id,
            &[],
            "replica deposed",
            tracked,
            place_queue,
            done_tx,
            metrics,
            cfg.max_failovers,
        );
    }
    let delay = restart_backoff_ms(cfg.restart_backoff_ms, slot.rep.restarts(), rng);
    slot.down_until = Some(now + Duration::from_millis(delay));
}

#[allow(clippy::too_many_arguments)]
fn router_loop(
    mut slots: Vec<Slot>,
    cmd_rx: Receiver<FleetMsg>,
    done_tx: Sender<Completion>,
    cfg: FleetConfig,
    faults: Faults,
    metrics: Arc<Mutex<FleetMetrics>>,
    statuses: Arc<Mutex<Vec<ReplicaStatus>>>,
    serve_handles: Arc<Mutex<Vec<Arc<Mutex<ServeMetrics>>>>>,
    pools: Arc<Mutex<Vec<Arc<KvPagePool>>>>,
) {
    let mut tracked: HashMap<u64, Tracked> = HashMap::new();
    let mut place_queue: VecDeque<u64> = VecDeque::new();
    let mut placer = Placer::new(cfg.seed);
    // deposed coordinators whose schedulers may still be mid-stall; their
    // joins are deferred to shutdown so the router never blocks on them
    let mut graveyard: Vec<Coordinator> = Vec::new();
    let mut backoff_rngs: Vec<Rng> = (0..slots.len())
        .map(|r| faults.fork_rng(&format!("replica_restart:{r}")))
        .collect();
    let stall = Duration::from_millis(cfg.stall_ms.max(1));
    let mut stopping = false;

    'router: loop {
        // -- commands ---------------------------------------------------
        let busy = !tracked.is_empty()
            || slots.iter().any(|s| {
                s.down_until.is_some() || s.restart_ack.is_some() || !s.drain_acks.is_empty()
            });
        let tick = if busy {
            Duration::from_micros(200)
        } else {
            Duration::from_millis(2)
        };
        let first = match cmd_rx.recv_timeout(tick) {
            Ok(msg) => Some(msg),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                stopping = true;
                None
            }
        };
        for msg in first.into_iter().chain(std::iter::from_fn(|| cmd_rx.try_recv().ok())) {
            match msg {
                FleetMsg::Submit(req) => {
                    let id = req.id;
                    if tracked.contains_key(&id) {
                        done_tx
                            .send(error_completion(
                                id,
                                Vec::new(),
                                format!("duplicate request id {id} still in flight"),
                            ))
                            .ok();
                        continue;
                    }
                    tracked.insert(
                        id,
                        Tracked {
                            req,
                            emitted: Vec::new(),
                            failovers: 0,
                            submitted: Instant::now(),
                        },
                    );
                    place_queue.push_back(id);
                }
                FleetMsg::Drain(r, ack) => {
                    if r < slots.len() && !slots[r].lost {
                        slots[r].draining = true;
                        slots[r].drain_acks.push(ack);
                        flock(&metrics).drains += 1;
                    } // else: ack dropped -> caller sees an error
                }
                FleetMsg::Restart(r, ack) => {
                    if r < slots.len() && !slots[r].lost {
                        slots[r].draining = true;
                        slots[r].restart_ack = Some(ack);
                    }
                }
                FleetMsg::Stop => stopping = true,
            }
        }
        if stopping {
            break 'router;
        }
        let now = Instant::now();

        // -- crash restarts due ------------------------------------------
        for r in 0..slots.len() {
            let due = slots[r].down_until.is_some_and(|t| now >= t);
            if !due {
                continue;
            }
            slots[r].down_until = None;
            if slots[r].rep.restarts() >= cfg.max_restarts {
                slots[r].lost = true;
                flock(&metrics).replicas_lost += 1;
                crate::log_warn!(
                    "fleet",
                    "replica {r} exhausted its restart budget; marking it lost"
                );
                continue;
            }
            let old = slots[r].rep.restart();
            graveyard.push(old);
            flock(&pools).push(slots[r].rep.pool());
            flock(&serve_handles)[r] = slots[r].rep.coord().metrics_arc();
            slots[r].hb_last = slots[r].rep.heartbeat();
            slots[r].hb_at = now;
            flock(&metrics).restarts += 1;
        }

        // -- planned (drain-gated) restarts ------------------------------
        for r in 0..slots.len() {
            if slots[r].restart_ack.is_none() || !slots[r].up() || !slots[r].outstanding.is_empty()
            {
                continue;
            }
            // idle and healthy: a joining stop is quick and drains nothing
            slots[r].rep.coord_mut().stop();
            drop(slots[r].rep.restart()); // old incarnation already joined
            flock(&pools).push(slots[r].rep.pool());
            flock(&serve_handles)[r] = slots[r].rep.coord().metrics_arc();
            slots[r].hb_last = slots[r].rep.heartbeat();
            slots[r].hb_at = now;
            slots[r].draining = false;
            {
                let mut m = flock(&metrics);
                m.restarts += 1;
                m.planned_restarts += 1;
            }
            // publish the new status before the ack so a caller blocked on
            // restart_replica() never reads the pre-restart state
            flock(&statuses)[r] = slots[r].status();
            if let Some(ack) = slots[r].restart_ack.take() {
                ack.send(()).ok();
            }
        }

        // -- place queued work -------------------------------------------
        let mut requeue: VecDeque<u64> = VecDeque::new();
        while let Some(id) = place_queue.pop_front() {
            if !tracked.contains_key(&id) {
                continue;
            }
            let views: Vec<ReplicaView> = slots.iter().map(Slot::view).collect();
            let Some((arrival, chosen)) = placer.place(&views) else {
                if slots.iter().all(|s| s.lost) {
                    let t = tracked.remove(&id).unwrap();
                    flock(&metrics).failed += 1;
                    done_tx
                        .send(error_completion(
                            id,
                            t.emitted,
                            "all replicas lost; request abandoned".into(),
                        ))
                        .ok();
                    continue;
                }
                // nothing eligible right now (restarting / draining /
                // degraded): nothing else will place this tick either
                requeue.push_back(id);
                requeue.extend(place_queue.drain(..));
                break;
            };
            flock(&metrics).events.push(PlacedEvent {
                arrival,
                id,
                views,
                chosen,
            });
            let rr = replay_request(&tracked[&id]);
            match slots[chosen].rep.coord().submit(rr) {
                Ok(()) => {
                    slots[chosen].outstanding.insert(id);
                    flock(&metrics).placed += 1;
                }
                Err(e) if e.to_string().contains("queue full") => {
                    // backpressure: retry next tick (the arrival index is
                    // spent; the event log records the refused attempt)
                    requeue.push_back(id);
                }
                Err(_) => {
                    // dead underneath us; depose now, requeue the request
                    flock(&metrics).replica_deaths += 1;
                    depose_slot(
                        &mut slots[chosen],
                        &mut backoff_rngs[chosen],
                        now,
                        &cfg,
                        &mut tracked,
                        &mut place_queue,
                        &done_tx,
                        &metrics,
                    );
                    requeue.push_back(id);
                }
            }
        }
        place_queue = requeue;

        // -- poll completions; a disconnect is a dead replica ------------
        let mut dead: Vec<usize> = Vec::new();
        for r in 0..slots.len() {
            if !slots[r].up() {
                continue;
            }
            loop {
                match slots[r].rep.coord().next_completion(Duration::ZERO) {
                    CompletionWait::Ready(c) => {
                        if !slots[r].outstanding.remove(&c.id) {
                            // fencing: a deposed incarnation's duplicate
                            flock(&metrics).stale_completions += 1;
                            continue;
                        }
                        forward_completion(
                            c,
                            &mut tracked,
                            &mut place_queue,
                            &done_tx,
                            &metrics,
                            cfg.max_failovers,
                            false,
                        );
                    }
                    CompletionWait::TimedOut => break,
                    CompletionWait::Disconnected => {
                        dead.push(r);
                        break;
                    }
                }
            }
        }
        for r in dead {
            flock(&metrics).replica_deaths += 1;
            depose_slot(
                &mut slots[r],
                &mut backoff_rngs[r],
                now,
                &cfg,
                &mut tracked,
                &mut place_queue,
                &done_tx,
                &metrics,
            );
        }

        // -- stall detection ---------------------------------------------
        for r in 0..slots.len() {
            if !slots[r].up() {
                continue;
            }
            let hb = slots[r].rep.heartbeat();
            if hb != slots[r].hb_last {
                slots[r].hb_last = hb;
                slots[r].hb_at = now;
            } else if now.duration_since(slots[r].hb_at) > stall {
                flock(&metrics).deposed_stalls += 1;
                depose_slot(
                    &mut slots[r],
                    &mut backoff_rngs[r],
                    now,
                    &cfg,
                    &mut tracked,
                    &mut place_queue,
                    &done_tx,
                    &metrics,
                );
            }
        }

        // -- publish statuses, then drain acknowledgements (a caller
        // -- unblocked by an ack must observe the draining status) -------
        {
            let mut st = flock(&statuses);
            for (r, slot) in slots.iter().enumerate() {
                st[r] = slot.status();
            }
        }
        for slot in &mut slots {
            if slot.draining && slot.outstanding.is_empty() && !slot.drain_acks.is_empty() {
                for ack in slot.drain_acks.drain(..) {
                    ack.send(()).ok();
                }
            }
        }
    }

    // -- shutdown: answer everything, then let the stream disconnect -----
    for slot in &mut slots {
        slot.rep.coord_mut().stop();
    }
    for r in 0..slots.len() {
        while let CompletionWait::Ready(c) =
            slots[r].rep.coord().next_completion(Duration::ZERO)
        {
            if !slots[r].outstanding.remove(&c.id) {
                flock(&metrics).stale_completions += 1;
                continue;
            }
            forward_completion(
                c,
                &mut tracked,
                &mut place_queue,
                &done_tx,
                &metrics,
                cfg.max_failovers,
                true,
            );
        }
    }
    let mut ids: Vec<u64> = tracked.keys().copied().collect();
    ids.sort_unstable();
    for id in ids {
        let t = tracked.remove(&id).unwrap();
        flock(&metrics).failed += 1;
        done_tx
            .send(error_completion(
                id,
                t.emitted,
                "fleet stopped before completion".into(),
            ))
            .ok();
    }
    for slot in &mut slots {
        for ack in slot.drain_acks.drain(..) {
            ack.send(()).ok();
        }
        if let Some(ack) = slot.restart_ack.take() {
            ack.send(()).ok();
        }
    }
    {
        let mut st = flock(&statuses);
        for (r, slot) in slots.iter().enumerate() {
            st[r] = slot.status();
        }
    }
    // deferred joins of deposed schedulers (bounded by their stalls)
    drop(graveyard);
}

/// Deliver a replica completion to the client — verbatim when the session
/// never failed over (the `--replicas 1` byte-identity path), stitched
/// onto the emitted prefix otherwise — or route it into failover.
fn forward_completion(
    c: Completion,
    tracked: &mut HashMap<u64, Tracked>,
    place_queue: &mut VecDeque<u64>,
    done_tx: &Sender<Completion>,
    metrics: &Mutex<FleetMetrics>,
    max_failovers: usize,
    terminal: bool,
) {
    match &c.error {
        Some(e) if !terminal && failover_eligible(e) => {
            let id = c.id;
            route_failover(
                id,
                &c.tokens,
                e,
                tracked,
                place_queue,
                done_tx,
                metrics,
                max_failovers,
            );
        }
        Some(_) => {
            let Some(t) = tracked.remove(&c.id) else { return };
            flock(metrics).failed += 1;
            if t.emitted.is_empty() {
                done_tx.send(c).ok();
            } else {
                let mut tokens = t.emitted;
                tokens.extend_from_slice(&c.tokens);
                done_tx
                    .send(Completion {
                        id: c.id,
                        tokens,
                        queue_secs: c.queue_secs,
                        ttft_secs: c.ttft_secs,
                        e2e_secs: t.submitted.elapsed().as_secs_f64(),
                        error: c.error,
                    })
                    .ok();
            }
        }
        None => {
            let Some(t) = tracked.remove(&c.id) else { return };
            if t.emitted.is_empty() {
                done_tx.send(c).ok();
            } else {
                let mut tokens = t.emitted;
                tokens.extend_from_slice(&c.tokens);
                done_tx
                    .send(Completion {
                        id: c.id,
                        tokens,
                        queue_secs: c.queue_secs,
                        ttft_secs: c.ttft_secs,
                        e2e_secs: t.submitted.elapsed().as_secs_f64(),
                        error: None,
                    })
                    .ok();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::engine::{AttnOptions, MlpMode};
    use crate::model::kv::KvOptions;
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn tiny_engine() -> Engine {
        tiny_engine_with_attn(AttnOptions::default())
    }

    fn tiny_engine_with_attn(attn: AttnOptions) -> Engine {
        let cfg = NativeConfig {
            name: "t".into(),
            kind: ModelKind::Llama,
            vocab: 48,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 48,
            block: 8,
        };
        let mut rng = Rng::new(7);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        Engine::new_with_opts(
            cfg,
            &s,
            &BTreeMap::new(),
            MlpMode::Sparse,
            KvOptions { page: 4, pool_pages: Some(32), prefix_cache: true },
            attn,
        )
        .unwrap()
    }

    fn view(id: usize, healthy: bool, draining: bool, load: usize) -> ReplicaView {
        ReplicaView { id, healthy, draining, load }
    }

    #[test]
    fn placer_picks_least_loaded_and_skips_ineligible() {
        let mut p = Placer::new(42);
        // unique minimum wins regardless of the tie-break hash
        let (a0, c) = p
            .place(&[view(0, true, false, 3), view(1, true, false, 1), view(2, true, false, 2)])
            .unwrap();
        assert_eq!((a0, c), (0, 1));
        // draining and unhealthy replicas are never chosen
        let (_, c) = p
            .place(&[view(0, true, true, 0), view(1, false, false, 0), view(2, true, false, 9)])
            .unwrap();
        assert_eq!(c, 2);
        // nothing eligible: no decision, no arrival consumed
        let before = p.arrivals();
        assert!(p.place(&[view(0, false, false, 0), view(1, true, true, 0)]).is_none());
        assert_eq!(p.arrivals(), before);
    }

    #[test]
    fn placer_tiebreak_is_a_pure_function_of_seed_and_arrival() {
        let ties = [view(0, true, false, 2), view(1, true, false, 2), view(2, true, false, 2)];
        let mut a = Placer::new(9);
        let mut b = Placer::new(9);
        let seq_a: Vec<_> = (0..32).map(|_| a.place(&ties).unwrap()).collect();
        let seq_b: Vec<_> = (0..32).map(|_| b.place(&ties).unwrap()).collect();
        assert_eq!(seq_a, seq_b, "same seed, same arrivals, same choices");
        // pinned against the transliterated hash
        for (arrival, chosen) in &seq_a {
            assert_eq!(*chosen, (placement_mix(9, *arrival) % 3) as usize);
        }
        // a different seed must disagree somewhere over 32 draws
        let mut c = Placer::new(10);
        let seq_c: Vec<_> = (0..32).map(|_| c.place(&ties).unwrap()).collect();
        assert_ne!(seq_a, seq_c);
    }

    #[test]
    fn restart_backoff_is_exponential_bounded_and_jittered() {
        let faults = crate::util::faults::Faults::parse("replica_crash:0.1:5").unwrap();
        let mut r1 = faults.fork_rng("replica_restart:0");
        let mut r2 = faults.fork_rng("replica_restart:0");
        for attempt in 0..10 {
            let d1 = restart_backoff_ms(5, attempt, &mut r1);
            let d2 = restart_backoff_ms(5, attempt, &mut r2);
            assert_eq!(d1, d2, "same fork, same schedule");
            let base = 5u64 << attempt.min(4);
            assert!(d1 >= base && d1 < base + 5, "attempt {attempt}: {d1} vs base {base}");
        }
    }

    /// A two-replica fleet serves a burst exactly once, spreads load per
    /// the placer, and every placement event replays through a fresh
    /// oracle `Placer` — placement is a pure function of (seed, arrival
    /// order, health snapshots).
    #[test]
    fn fleet_serves_exactly_once_and_placement_replays() {
        let base = tiny_engine();
        let cfg = FleetConfig { replicas: 2, seed: 3, ..FleetConfig::default() };
        let mut fleet = Fleet::start(&base, cfg);
        let n = 10u64;
        for i in 0..n {
            fleet
                .submit(Request {
                    id: i,
                    prompt: vec![1 + i as u32 % 4, 2, 3],
                    max_new: 4,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut seen = HashSet::new();
        while seen.len() < n as usize {
            match fleet.next_completion(Duration::from_secs(30)) {
                CompletionWait::Ready(c) => {
                    assert!(c.error.is_none(), "request {} failed: {:?}", c.id, c.error);
                    assert!(!c.tokens.is_empty());
                    assert!(seen.insert(c.id), "request {} answered twice", c.id);
                }
                other => panic!("stream ended early: {other:?}"),
            }
        }
        let m = fleet.metrics();
        assert_eq!(m.placed, n);
        assert_eq!(m.failovers + m.restarts + m.replica_deaths + m.deposed_stalls, 0);
        // both replicas actually served
        let used: HashSet<usize> = m.events.iter().map(|e| e.chosen).collect();
        assert_eq!(used.len(), 2, "least-loaded placement must use both replicas");
        // purity: replay the event log through a fresh placer
        let mut oracle = Placer::new(cfg.seed);
        for ev in &m.events {
            let (arrival, chosen) = oracle.place(&ev.views).expect("oracle found no replica");
            assert_eq!((arrival, chosen), (ev.arrival, ev.chosen), "event {ev:?}");
        }
        fleet.stop();
        for p in fleet.pools() {
            assert_eq!(p.pages_in_use(), 0, "a pool kept pages after stop");
        }
        assert!(matches!(
            fleet.next_completion(Duration::from_millis(10)),
            CompletionWait::Disconnected
        ));
    }

    /// Draining stops placements without dropping anything; a planned
    /// restart brings the replica back with a fresh incarnation that
    /// resumes taking load.
    #[test]
    fn drain_and_planned_restart_drop_nothing() {
        let base = tiny_engine();
        let cfg = FleetConfig { replicas: 2, seed: 1, ..FleetConfig::default() };
        let mut fleet = Fleet::start(&base, cfg);
        for i in 0..4u64 {
            fleet
                .submit(Request { id: i, prompt: vec![1, 2, 3], max_new: 3, ..Default::default() })
                .unwrap();
        }
        fleet.drain(0).unwrap();
        assert_eq!(fleet.statuses()[0], ReplicaStatus::Draining);
        let before = fleet.metrics().events.len();
        for i in 4..8u64 {
            fleet
                .submit(Request { id: i, prompt: vec![2, 3, 4], max_new: 3, ..Default::default() })
                .unwrap();
        }
        let mut seen = HashSet::new();
        while seen.len() < 8 {
            match fleet.next_completion(Duration::from_secs(30)) {
                CompletionWait::Ready(c) => {
                    assert!(c.error.is_none(), "{:?}", c.error);
                    assert!(seen.insert(c.id));
                }
                other => panic!("stream ended early: {other:?}"),
            }
        }
        let m = fleet.metrics();
        assert!(
            m.events[before..].iter().all(|e| e.chosen == 1),
            "placements after drain(0) must all land on replica 1"
        );
        fleet.restart_replica(0).unwrap();
        assert_eq!(fleet.statuses()[0], ReplicaStatus::Healthy);
        let m = fleet.metrics();
        assert_eq!((m.planned_restarts, m.drains), (1, 1));
        assert_eq!(m.failed, 0, "drain/restart must drop nothing");
        // the cycled replica takes load again: a solo drain of 1 forces it
        fleet.drain(1).unwrap();
        fleet
            .submit(Request { id: 100, prompt: vec![3, 2, 1], max_new: 3, ..Default::default() })
            .unwrap();
        match fleet.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => assert!(c.error.is_none(), "{:?}", c.error),
            other => panic!("stream ended early: {other:?}"),
        }
        assert_eq!(fleet.metrics().events.last().unwrap().chosen, 0);
        fleet.stop();
        for p in fleet.pools() {
            assert_eq!(p.pages_in_use(), 0);
        }
    }

    /// A threshold-armed fleet serves a burst exactly once and surfaces
    /// an aggregated skip digest; an exact fleet never grows one, so its
    /// summary stays byte-identical to pre-threshold output.
    #[test]
    fn fleet_aggregates_attn_skip_counters() {
        let exact = Fleet::start(
            &tiny_engine(),
            FleetConfig { replicas: 2, seed: 11, ..FleetConfig::default() },
        );
        assert!(exact.attn_aggregate().is_none());
        assert!(!exact.metrics_summary().contains("attn_"), "{}", exact.metrics_summary());

        let base = tiny_engine_with_attn(AttnOptions { threshold: Some(1e30) });
        let mut fleet = Fleet::start(
            &base,
            FleetConfig { replicas: 2, seed: 11, ..FleetConfig::default() },
        );
        let n = 8u64;
        for i in 0..n {
            fleet
                .submit(Request {
                    id: i,
                    prompt: vec![1 + i as u32 % 4, 2, 3, 4, 5],
                    max_new: 6,
                    ..Default::default()
                })
                .unwrap();
        }
        let mut seen = HashSet::new();
        while seen.len() < n as usize {
            match fleet.next_completion(Duration::from_secs(30)) {
                CompletionWait::Ready(c) => {
                    assert!(c.error.is_none(), "request {} failed: {:?}", c.id, c.error);
                    assert!(seen.insert(c.id));
                }
                other => panic!("stream ended early: {other:?}"),
            }
        }
        let agg = fleet.attn_aggregate().expect("armed fleet must engage counters");
        assert!(agg.rows > 0 && agg.pages > 0, "{agg:?}");
        // τ=1e30 visits everything and skips nothing
        assert_eq!(agg.rows_skipped, 0, "{agg:?}");
        assert_eq!(agg.pages_skipped, 0, "{agg:?}");
        // skipped ≤ visited holds per replica too
        for h in flock(&fleet.serve_handles).iter() {
            let a = flock(h).attn;
            assert!(a.rows_skipped <= a.rows && a.pages_skipped <= a.pages, "{a:?}");
        }
        let s = fleet.metrics_summary();
        assert!(s.contains("fleet attn: rows_skipped=0/"), "{s}");
        fleet.stop();
        for p in fleet.pools() {
            assert_eq!(p.pages_in_use(), 0);
        }
    }

    /// Stopping a fleet with work still queued answers every request —
    /// success or explicit error, never silence.
    #[test]
    fn stop_answers_everything_tracked() {
        let base = tiny_engine();
        let mut fleet = Fleet::start(
            &base,
            FleetConfig { replicas: 2, seed: 5, ..FleetConfig::default() },
        );
        for i in 0..6u64 {
            fleet
                .submit(Request { id: i, prompt: vec![1, 2], max_new: 30, ..Default::default() })
                .unwrap();
        }
        fleet.stop();
        let mut seen = HashSet::new();
        loop {
            match fleet.next_completion(Duration::from_millis(100)) {
                CompletionWait::Ready(c) => {
                    assert!(seen.insert(c.id), "request {} answered twice", c.id);
                }
                CompletionWait::Disconnected => break,
                CompletionWait::TimedOut => panic!("stream neither drained nor closed"),
            }
        }
        assert_eq!(seen.len(), 6, "every submitted request must be answered");
        for p in fleet.pools() {
            assert_eq!(p.pages_in_use(), 0);
        }
    }
}
