//! One supervised serving replica: an [`Engine`] fork (own
//! [`crate::model::KvPagePool`], shared prepacked weights), a
//! [`Coordinator`] scheduler thread, a heartbeat counter and per-replica
//! [`crate::coordinator::ServeMetrics`] — the unit the fleet tier places
//! sessions on, deposes when it stalls, and restarts when it dies.
//!
//! A replica is identified by `(id, incarnation)`: the id is its fixed
//! slot in the fleet, the incarnation bumps on every restart. Each
//! incarnation gets
//!
//! * a **fresh engine fork** ([`Engine::fork_with_fresh_kv`]): the packed
//!   weights are shared through one `Arc` (restart never re-packs), the
//!   KV pool — pages, prefix index, high-water marks — starts empty;
//! * a **forked fault plan** ([`Faults::fork`] with salt
//!   `(id << 32) | incarnation`): every incarnation draws its own
//!   deterministic per-site RNG streams, so a chaos run's kill schedule
//!   replays bit-for-bit regardless of thread interleaving. Replica 0's
//!   first incarnation uses salt 0, i.e. exactly the root plan — which is
//!   what makes a 1-replica fleet behave byte-identically to a bare
//!   [`Coordinator`].

use std::sync::Arc;

use crate::coordinator::router::BatcherConfig;
use crate::coordinator::server::{Coordinator, HealthState};
use crate::model::engine::Engine;
use crate::model::kv::KvPagePool;
use crate::util::faults::Faults;

/// Fault/jitter stream salt for `(replica id, incarnation)`. Salt 0 —
/// replica 0, incarnation 0 — reproduces the root plan exactly.
pub fn replica_salt(id: usize, incarnation: u64) -> u64 {
    ((id as u64) << 32) | (incarnation & 0xFFFF_FFFF)
}

/// A supervised replica: the current [`Coordinator`] incarnation plus the
/// bookkeeping to build the next one.
pub struct Replica {
    id: usize,
    incarnation: u64,
    restarts: u64,
    cfg: BatcherConfig,
    faults_root: Faults,
    engine: Arc<Engine>,
    coord: Coordinator,
}

impl Replica {
    /// Start incarnation 0 of replica `id`: fork `base` (fresh pool, shared
    /// weights) and spawn its scheduler with the per-replica fault fork.
    pub fn start(id: usize, base: &Engine, cfg: BatcherConfig, faults_root: Faults) -> Replica {
        let engine = Arc::new(base.fork_with_fresh_kv());
        let coord = Coordinator::start_with_faults(
            engine.clone(),
            cfg,
            faults_root.fork(replica_salt(id, 0)),
        );
        Replica {
            id,
            incarnation: 0,
            restarts: 0,
            cfg,
            faults_root,
            engine,
            coord,
        }
    }

    /// Replace the current incarnation with a fresh one — new engine fork
    /// (empty pool), new scheduler thread, next fault-fork salt — and
    /// return the **old** coordinator so the caller can keep it in a
    /// graveyard until it is safe to join (a deposed-but-stalled scheduler
    /// must not block the fleet router on its sleep).
    pub fn restart(&mut self) -> Coordinator {
        self.incarnation += 1;
        self.restarts += 1;
        let engine = Arc::new(self.engine.fork_with_fresh_kv());
        let coord = Coordinator::start_with_faults(
            engine.clone(),
            self.cfg,
            self.faults_root.fork(replica_salt(self.id, self.incarnation)),
        );
        self.engine = engine;
        std::mem::replace(&mut self.coord, coord)
    }

    /// Fixed fleet slot of this replica.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Restart generation (0 = the original incarnation).
    pub fn incarnation(&self) -> u64 {
        self.incarnation
    }

    /// Times this replica has been restarted.
    pub fn restarts(&self) -> u64 {
        self.restarts
    }

    /// The current incarnation's coordinator.
    pub fn coord(&self) -> &Coordinator {
        &self.coord
    }

    /// The current incarnation's coordinator, mutably (stop/join).
    pub fn coord_mut(&mut self) -> &mut Coordinator {
        &mut self.coord
    }

    /// The current incarnation's KV pool (drain accounting).
    pub fn pool(&self) -> Arc<KvPagePool> {
        self.engine.kv_pool().clone()
    }

    /// Health of the current incarnation's scheduler.
    pub fn health(&self) -> HealthState {
        self.coord.health()
    }

    /// Heartbeat of the current incarnation's scheduler.
    pub fn heartbeat(&self) -> u64 {
        self.coord.heartbeat()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Request;
    use crate::coordinator::server::CompletionWait;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::engine::MlpMode;
    use crate::model::kv::KvOptions;
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn tiny_engine() -> Engine {
        let cfg = NativeConfig {
            name: "t".into(),
            kind: ModelKind::Llama,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 32,
            block: 8,
        };
        let mut rng = Rng::new(1);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        Engine::new_with_kv(
            cfg,
            &s,
            &BTreeMap::new(),
            MlpMode::Sparse,
            KvOptions { page: 4, pool_pages: Some(16), prefix_cache: true },
        )
        .unwrap()
    }

    fn serve_one(r: &Replica, id: u64) -> Vec<u32> {
        r.coord()
            .submit(Request {
                id,
                prompt: vec![1, 2, 3],
                max_new: 4,
                ..Default::default()
            })
            .unwrap();
        match r.coord().next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                assert!(c.error.is_none(), "{:?}", c.error);
                c.tokens
            }
            other => panic!("no completion: {other:?}"),
        }
    }

    /// Restart rebuilds the scheduler on a fresh pool over shared weights:
    /// the incarnation bumps, the old incarnation's pool drains, the new
    /// one serves the same streams from a cold cache.
    #[test]
    fn restart_serves_identical_streams_on_fresh_pool() {
        let base = tiny_engine();
        let mut rep = Replica::start(3, &base, BatcherConfig::default(), Faults::disabled());
        assert_eq!((rep.id(), rep.incarnation(), rep.restarts()), (3, 0, 0));
        let first = serve_one(&rep, 0);
        let old_pool = rep.pool();
        let mut old = rep.restart();
        assert_eq!((rep.incarnation(), rep.restarts()), (1, 1));
        old.stop();
        assert_eq!(old_pool.pages_in_use(), 0, "old incarnation's pool must drain");
        // same request on the new incarnation: bit-identical stream
        let second = serve_one(&rep, 1);
        assert_eq!(first, second);
        assert!(!Arc::ptr_eq(&old_pool, &rep.pool()), "restart must not reuse the pool");
        rep.coord_mut().stop();
        assert_eq!(rep.pool().pages_in_use(), 0);
    }

    /// Each `(id, incarnation)` draws its own deterministic fault stream:
    /// the salt layout is pinned so chaos runs replay across processes.
    #[test]
    fn replica_salts_are_unique_and_pinned() {
        assert_eq!(replica_salt(0, 0), 0, "replica 0 inc 0 must be the root plan");
        assert_eq!(replica_salt(1, 0), 1 << 32);
        assert_eq!(replica_salt(0, 1), 1);
        assert_eq!(replica_salt(2, 3), (2u64 << 32) | 3);
        let mut seen = std::collections::HashSet::new();
        for id in 0..8 {
            for inc in 0..8 {
                assert!(seen.insert(replica_salt(id, inc)));
            }
        }
    }
}
