//! Pure request routing + continuous batching state machine.
//!
//! Separated from the threaded server so its invariants are directly
//! testable: bounded queue (backpressure), FIFO admission, no starvation,
//! at most `max_batch` active sessions, and every session terminates at
//! `max_new` tokens or EOS.

use std::collections::VecDeque;

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen id, echoed back in the [`crate::coordinator::Completion`].
    pub id: u64,
    /// Prompt token ids (must fit the engine's `max_seq`).
    pub prompt: Vec<u32>,
    /// Decode budget: at most this many new tokens are generated.
    pub max_new: usize,
    /// Optional stop token.
    pub eos: Option<u32>,
    /// Optional deadline in milliseconds from submission. A request still
    /// queued past its deadline is expired with an error completion; an
    /// in-flight session past it retires at the next round boundary with
    /// its partial output and a deadline error (so a client never waits
    /// more than one round beyond the deadline).
    pub deadline_ms: Option<u64>,
}

impl Default for Request {
    fn default() -> Self {
        Request {
            id: 0,
            prompt: Vec::new(),
            max_new: 0,
            eos: None,
            deadline_ms: None,
        }
    }
}

/// One admitted, in-flight sequence.
#[derive(Debug)]
pub struct Session {
    /// The originating request (its `max_new` may be lowered to force
    /// retirement when the engine cannot continue the session).
    pub req: Request,
    /// Generated tokens so far.
    pub output: Vec<u32>,
    /// Decode position = prompt len + generated (set after prefill).
    pub prefilled: bool,
    /// Round index at admission (for fairness accounting).
    pub admitted_round: u64,
}

impl Session {
    /// `true` once the decode budget is spent or EOS was emitted.
    pub fn finished(&self) -> bool {
        if self.output.len() >= self.req.max_new {
            return true;
        }
        match (self.req.eos, self.output.last()) {
            (Some(e), Some(&t)) => t == e,
            _ => false,
        }
    }
}

/// Scheduling knobs for the continuous batcher.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max concurrently active sessions (continuous batch width).
    pub max_batch: usize,
    /// Bounded waiting queue — enqueue beyond this is rejected
    /// (backpressure to the client).
    pub max_queue: usize,
    /// Drive each decode round through one `Engine::decode_batch` call
    /// (a single packed GEMM/BSpMM per projection over the whole batch)
    /// instead of per-session `decode` GEMV chains. On by default; turn
    /// off only for the sequential A/B baseline — greedy outputs are
    /// bit-identical either way.
    pub batched: bool,
    /// Bounded retries (with jittered backoff) for a *transient* batched
    /// decode-round failure before falling back to per-session sequential
    /// decode. Panics and pool-exhaustion errors are never retried.
    pub round_retries: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 4,
            max_queue: 64,
            batched: true,
            round_retries: 2,
        }
    }
}

/// Admission verdict for one waiting request (see [`Batcher::admit_where`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admit {
    /// Admit now.
    Grant,
    /// Cannot be admitted *yet* (e.g. the KV page pool lacks free pages);
    /// stays at the front of the queue — admission stops here so FIFO
    /// order (and the no-starvation property) is preserved.
    Defer,
    /// Can never be admitted (e.g. the prompt needs more KV pages than
    /// the pool's total capacity); removed from the queue and handed back
    /// to the caller to answer with an error completion.
    Refuse,
}

/// Continuous batcher: FIFO waiting queue + bounded active set.
pub struct Batcher {
    cfg: BatcherConfig,
    waiting: VecDeque<Request>,
    active: Vec<Session>,
    round: u64,
    /// Requests refused — waiting-queue overflow at [`Batcher::enqueue`]
    /// or an admission-time [`Admit::Refuse`] (e.g. a prompt that could
    /// never fit the KV page pool) at [`Batcher::admit_where`].
    pub rejected: u64,
    /// Sessions retired so far.
    pub completed: u64,
}

impl Batcher {
    /// An empty batcher with the given limits.
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            waiting: VecDeque::new(),
            active: Vec::new(),
            round: 0,
            rejected: 0,
            completed: 0,
        }
    }

    /// Requests waiting for a batch slot.
    pub fn queue_len(&self) -> usize {
        self.waiting.len()
    }

    /// Sessions currently in flight.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Decode rounds completed since start.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Try to enqueue; `false` = queue full (backpressure).
    pub fn enqueue(&mut self, req: Request) -> bool {
        if self.waiting.len() >= self.cfg.max_queue {
            self.rejected += 1;
            return false;
        }
        self.waiting.push_back(req);
        true
    }

    /// Admit FIFO-waiting requests into free batch slots. Returns indices
    /// of the newly admitted sessions (which still need prefill).
    pub fn admit(&mut self) -> Vec<usize> {
        self.admit_where(|_| Admit::Grant).0
    }

    /// Admit FIFO-waiting requests into free batch slots, subject to a
    /// per-request verdict (the coordinator's KV-pool capacity check).
    /// Returns `(indices of newly admitted sessions, refused requests)`.
    /// A [`Admit::Defer`] stops admission at the queue front — later
    /// requests are *not* considered, so FIFO fairness holds; refused
    /// requests count toward [`Batcher::rejected`].
    pub fn admit_where(
        &mut self,
        mut decide: impl FnMut(&Request) -> Admit,
    ) -> (Vec<usize>, Vec<Request>) {
        let mut new_idx = Vec::new();
        let mut refused = Vec::new();
        while self.active.len() < self.cfg.max_batch {
            let Some(front) = self.waiting.front() else { break };
            match decide(front) {
                Admit::Defer => break,
                Admit::Refuse => {
                    self.rejected += 1;
                    refused.push(self.waiting.pop_front().unwrap());
                }
                Admit::Grant => {
                    let req = self.waiting.pop_front().unwrap();
                    self.active.push(Session {
                        req,
                        output: Vec::new(),
                        prefilled: false,
                        admitted_round: self.round,
                    });
                    new_idx.push(self.active.len() - 1);
                }
            }
        }
        (new_idx, refused)
    }

    /// Access the active sessions for one decode round.
    pub fn active_mut(&mut self) -> &mut [Session] {
        &mut self.active
    }

    /// Advance a round: retire finished sessions, bump the round counter.
    /// Returns the retired sessions.
    pub fn end_round(&mut self) -> Vec<Session> {
        self.round += 1;
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.swap_remove(i));
                self.completed += 1;
            } else {
                i += 1;
            }
        }
        done
    }

    /// `true` when there is nothing queued and nothing in flight.
    pub fn idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty()
    }

    /// Remove and return the waiting requests matching `expired` (the
    /// coordinator's queued-past-deadline sweep), preserving the FIFO
    /// order of everything else. Expired requests count as rejected.
    pub fn expire_where(&mut self, mut expired: impl FnMut(&Request) -> bool) -> Vec<Request> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.waiting.len() {
            if expired(&self.waiting[i]) {
                out.push(self.waiting.remove(i).unwrap());
                self.rejected += 1;
            } else {
                i += 1;
            }
        }
        out
    }

    /// Remove and return every waiting (queued-but-unadmitted) request —
    /// the shutdown path, so the server can turn them into error
    /// completions instead of silently dropping them.
    pub fn drain_waiting(&mut self) -> Vec<Request> {
        self.waiting.drain(..).collect()
    }

    /// Remove and return every in-flight session (shutdown path); their
    /// partial outputs travel with them.
    pub fn take_active(&mut self) -> Vec<Session> {
        std::mem::take(&mut self.active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;

    fn req(id: u64, max_new: usize) -> Request {
        Request {
            id,
            prompt: vec![1, 2],
            max_new,
            ..Default::default()
        }
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_queue: 3,
            ..BatcherConfig::default()
        });
        for i in 0..3 {
            assert!(b.enqueue(req(i, 1)));
        }
        assert!(!b.enqueue(req(99, 1)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn fifo_admission_and_cap() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_queue: 10,
            ..BatcherConfig::default()
        });
        for i in 0..5 {
            b.enqueue(req(i, 1));
        }
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.active_mut()[0].req.id, 0);
        assert_eq!(b.active_mut()[1].req.id, 1);
        assert_eq!(b.queue_len(), 3);
    }

    #[test]
    fn retire_then_refill() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_queue: 10,
            ..BatcherConfig::default()
        });
        for i in 0..4 {
            b.enqueue(req(i, 1));
        }
        b.admit();
        // simulate one decode: everyone produced their 1 allowed token
        for s in b.active_mut() {
            s.output.push(7);
        }
        let done = b.end_round();
        assert_eq!(done.len(), 2);
        let admitted = b.admit();
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.active_mut()[0].req.id, 2);
    }

    #[test]
    fn admit_where_defer_preserves_fifo_and_refuse_removes() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_queue: 10,
            ..BatcherConfig::default()
        });
        for i in 0..4 {
            b.enqueue(req(i, 1));
        }
        // refuse id 0, grant id 1, defer at id 2 — id 3 must NOT be
        // considered (FIFO: no skipping past a deferred head)
        let mut seen = Vec::new();
        let (admitted, refused) = b.admit_where(|r| {
            seen.push(r.id);
            match r.id {
                0 => Admit::Refuse,
                1 => Admit::Grant,
                _ => Admit::Defer,
            }
        });
        assert_eq!(seen, vec![0, 1, 2]);
        assert_eq!(admitted.len(), 1);
        assert_eq!(b.active_mut()[admitted[0]].req.id, 1);
        assert_eq!(refused.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.rejected, 1);
        // ids 2 and 3 still waiting, in order
        assert_eq!(b.queue_len(), 2);
        let (admitted, refused) = b.admit_where(|_| Admit::Grant);
        assert_eq!(admitted.len(), 2);
        assert!(refused.is_empty());
        assert_eq!(b.active_mut()[1].req.id, 2);
        assert_eq!(b.active_mut()[2].req.id, 3);
    }

    #[test]
    fn expire_where_removes_matches_and_keeps_fifo_order() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_queue: 10,
            ..BatcherConfig::default()
        });
        for i in 0..5 {
            b.enqueue(req(i, 1));
        }
        let expired = b.expire_where(|r| r.id % 2 == 1);
        assert_eq!(expired.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.rejected, 2);
        assert_eq!(b.queue_len(), 3);
        let (admitted, _) = b.admit_where(|_| Admit::Grant);
        assert_eq!(admitted.len(), 2);
        assert_eq!(b.active_mut()[0].req.id, 0);
        assert_eq!(b.active_mut()[1].req.id, 2);
    }

    #[test]
    fn drain_and_take_empty_everything() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 2,
            max_queue: 10,
            ..BatcherConfig::default()
        });
        for i in 0..5 {
            b.enqueue(req(i, 3));
        }
        b.admit();
        let waiting = b.drain_waiting();
        assert_eq!(waiting.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3, 4]);
        let active = b.take_active();
        assert_eq!(active.len(), 2);
        assert!(b.idle());
        assert_eq!(b.queue_len(), 0);
    }

    #[test]
    fn eos_stops_early() {
        let mut s = Session {
            req: Request {
                id: 0,
                prompt: vec![1],
                max_new: 100,
                eos: Some(5),
                ..Default::default()
            },
            output: vec![3, 5],
            prefilled: true,
            admitted_round: 0,
        };
        assert!(s.finished());
        s.output = vec![3, 4];
        assert!(!s.finished());
    }

    /// Simulated full run: every enqueued request completes, admission is
    /// FIFO, active never exceeds max_batch, and no request waits forever
    /// (no starvation) — the coordinator invariants from DESIGN.md §9.
    #[test]
    fn no_starvation_property() {
        prop::check_default("batcher-no-starvation", |rng| {
            let max_batch = prop::usize_in(rng, 1, 4);
            let n_reqs = prop::usize_in(rng, 1, 30);
            let mut b = Batcher::new(BatcherConfig {
                max_batch,
                max_queue: 64,
                ..BatcherConfig::default()
            });
            for i in 0..n_reqs {
                b.enqueue(Request {
                    id: i as u64,
                    prompt: vec![1],
                    max_new: prop::usize_in(rng, 1, 5),
                    ..Default::default()
                });
            }
            let mut completion_order = Vec::new();
            let mut rounds = 0;
            while !b.idle() {
                rounds += 1;
                prop_assert!(rounds < 10_000, "scheduler did not converge");
                b.admit();
                prop_assert!(
                    b.active_len() <= max_batch,
                    "active {} > max {max_batch}",
                    b.active_len()
                );
                for s in b.active_mut() {
                    s.prefilled = true;
                    s.output.push(0); // one decoded token per round
                }
                for s in b.end_round() {
                    completion_order.push(s.req.id);
                }
            }
            prop_assert!(
                completion_order.len() == n_reqs,
                "{} of {n_reqs} completed",
                completion_order.len()
            );
            // FIFO fairness: a request can never finish more than
            // (max_new_max rounds) after one admitted later... weaker but
            // sufficient check: admission order == id order
            Ok(())
        });
    }
}
