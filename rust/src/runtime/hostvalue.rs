//! Host-side values crossing the runtime boundary.
//!
//! [`HostValue`] is the typed buffer exchanged with the PJRT executor (or
//! its stub): shape + dtype + data, convertible to/from [`Tensor`]. It is
//! independent of the `xla` crate so the serving/eval stack compiles with
//! or without the `pjrt` feature.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn from_tensor(t: &Tensor) -> HostValue {
        HostValue::F32 {
            shape: t.shape().to_vec(),
            data: t.data().to_vec(),
        }
    }

    pub fn tensor(t: Tensor) -> HostValue {
        HostValue::F32 {
            shape: t.shape().to_vec(),
            data: t.into_data(),
        }
    }

    pub fn scalar_i32(v: i32) -> HostValue {
        HostValue::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn scalar_f32(v: f32) -> HostValue {
        HostValue::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn i32s(shape: &[usize], data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostValue::F32 { .. } => "float32",
            HostValue::I32 { .. } => "int32",
        }
    }

    /// Unwrap as an f32 tensor.
    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            HostValue::F32 { shape, data } => Ok(Tensor::new(&shape, data)),
            HostValue::I32 { .. } => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 value"),
        }
    }

    /// Scalar f32 (loss values etc.).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostValue::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => bail!(
                "expected scalar f32, got {:?} {:?}",
                other.dtype(),
                other.shape()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostvalue_roundtrip_shapes() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = HostValue::from_tensor(&t);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), "float32");
        assert_eq!(v.into_tensor().unwrap(), t);
        let s = HostValue::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn scalar_accessor_rejects_nonscalar() {
        let v = HostValue::F32 {
            shape: vec![2],
            data: vec![1.0, 2.0],
        };
        assert!(v.scalar().is_err());
    }
}
