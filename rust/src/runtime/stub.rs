//! API-compatible stand-in for the PJRT runtime (default build).
//!
//! The real executor ([`crate::runtime::client`], behind the `pjrt` cargo
//! feature) needs the vendored `xla` crate and the AOT artifacts produced
//! by `make artifacts`. This stub keeps every caller compiling — the
//! trainer, the eval drivers, examples and integration tests all hold
//! `&Runtime` — while `Runtime::open*` reports clearly why execution is
//! unavailable. The value of the default build is the native kernel stack
//! ([`crate::kernels`], [`crate::model::engine`]), which never touches
//! PJRT.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::hostvalue::HostValue;
use crate::runtime::manifest::Manifest;

enum Never {}

/// Uninhabited stand-in for the PJRT runtime: `open*` always fails, so no
/// value of this type ever exists and the execution methods are provably
/// unreachable.
pub struct Runtime {
    never: Never,
    manifest: Manifest,
}

impl Runtime {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn open(dir: &Path) -> Result<Runtime> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (artifact dir {dir:?}); rebuild with `--features pjrt` and the \
             vendored `xla` dependency to execute AOT artifacts — the native \
             kernel stack works without it"
        )
    }

    /// Default artifact location relative to the crate root.
    pub fn open_default() -> Result<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::open(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an entry point (unreachable: `open` never succeeds).
    pub fn execute(&self, _entry: &str, _inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        match self.never {}
    }

    /// Map output name → value for an executed entry (unreachable).
    pub fn execute_named(
        &self,
        _entry: &str,
        _inputs: &[HostValue],
    ) -> Result<BTreeMap<String, HostValue>> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_reports_missing_feature() {
        // no unwrap_err(): the uninhabited Runtime has no Debug impl
        let err = match Runtime::open_default() {
            Err(e) => e,
            Ok(_) => unreachable!("stub open must fail"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
    }
}
