//! PJRT runtime bridge — loads the AOT artifacts and executes them.
//!
//! `make artifacts` (the only Python invocation) lowers every L2 entry
//! point to HLO text plus a JSON manifest describing the flat positional
//! ABI. This module is the Rust side of that contract:
//!
//! * [`manifest`] — parse `artifacts/manifest.json` into typed structs.
//! * [`client`] — wrap `xla::PjRtClient`: compile each HLO module once
//!   (cached), validate call shapes against the manifest, convert between
//!   [`crate::tensor::Tensor`] / host buffers and `xla::Literal`.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;

pub use client::{HostValue, Runtime};
pub use manifest::{ConfigInfo, EntryInfo, IoSpec, Manifest};
