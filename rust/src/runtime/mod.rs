//! PJRT runtime bridge — loads the AOT artifacts and executes them.
//!
//! `make artifacts` (the only Python invocation) lowers every L2 entry
//! point to HLO text plus a JSON manifest describing the flat positional
//! ABI. This module is the Rust side of that contract:
//!
//! * [`manifest`] — parse `artifacts/manifest.json` into typed structs.
//! * [`hostvalue`] — the typed host buffers crossing the boundary
//!   (independent of the `xla` crate).
//! * [`client`] — wrap `xla::PjRtClient`: compile each HLO module once
//!   (cached), validate call shapes against the manifest, convert between
//!   [`crate::tensor::Tensor`] / host buffers and `xla::Literal`. Compiled
//!   only with the `pjrt` cargo feature; the default build substitutes
//!   [`stub`], whose `Runtime::open*` fails with a descriptive error so
//!   the dependency-free native kernel stack remains fully usable.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see /opt/xla-example/README.md).

#[cfg(feature = "pjrt")]
pub mod client;
pub mod hostvalue;
pub mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
pub use hostvalue::HostValue;
pub use manifest::{ConfigInfo, EntryInfo, IoSpec, Manifest};
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;
