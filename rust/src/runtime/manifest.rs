//! Typed view of `artifacts/manifest.json` — the ABI contract emitted by
//! `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One input/output of an entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "float32" | "int32"
    pub dtype: String,
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct EntryInfo {
    pub name: String,
    pub file: String,
    /// Config name, or None for standalone kernel artifacts.
    pub config: Option<String>,
    /// "train_step" | "eval_loss" | "eval_loss_pallas" | "prefill"
    /// | "decode_step" | "kernel"
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
}

/// Geometry + ABI of one model config (a scaled twin of a paper geometry).
#[derive(Clone, Debug)]
pub struct ConfigInfo {
    pub name: String,
    /// "gpt2" | "llama" | "vit"
    pub kind: String,
    pub vocab: usize,
    pub emb: usize,
    pub ffn: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub seq: usize,
    pub batch: usize,
    pub block: usize,
    pub num_classes: usize,
    pub patch_dim: usize,
    pub lr: f64,
    pub param_count: usize,
    pub paper_equiv: String,
    /// Ordered (name, shape) — the positional parameter ABI.
    pub params: Vec<(String, Vec<usize>)>,
    /// Ordered (mlp-weight name, block-mask shape).
    pub masks: Vec<(String, Vec<usize>)>,
    pub mlp_weights: Vec<String>,
}

impl ConfigInfo {
    pub fn param_shape(&self, name: &str) -> Option<&[usize]> {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.as_slice())
    }

    /// Layer index encoded in a weight name like `layer3.mlp.w1`.
    pub fn layer_of(name: &str) -> Option<usize> {
        name.strip_prefix("layer")?
            .split('.')
            .next()?
            .parse()
            .ok()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub configs: BTreeMap<String, ConfigInfo>,
    pub entries: BTreeMap<String, EntryInfo>,
    pub adam: (f64, f64, f64),
}

fn shape_of(j: &Json) -> Vec<usize> {
    j.as_arr()
        .expect("shape must be an array")
        .iter()
        .map(|d| d.as_usize().expect("dim"))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut configs = BTreeMap::new();
        for (cname, cj) in j.req("configs").as_obj().context("configs")? {
            let params = cj
                .req("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| (p.str_or("name", ""), shape_of(p.req("shape"))))
                .collect();
            let masks = cj
                .req("masks")
                .as_arr()
                .context("masks")?
                .iter()
                .map(|p| (p.str_or("name", ""), shape_of(p.req("shape"))))
                .collect();
            let mlp_weights = cj
                .req("mlp_weights")
                .as_arr()
                .context("mlp_weights")?
                .iter()
                .map(|w| w.as_str().unwrap_or("").to_string())
                .collect();
            configs.insert(
                cname.clone(),
                ConfigInfo {
                    name: cname.clone(),
                    kind: cj.str_or("kind", ""),
                    vocab: cj.usize_or("vocab", 0),
                    emb: cj.usize_or("emb", 0),
                    ffn: cj.usize_or("ffn", 0),
                    layers: cj.usize_or("layers", 0),
                    heads: cj.usize_or("heads", 0),
                    head_dim: cj.usize_or("head_dim", 0),
                    seq: cj.usize_or("seq", 0),
                    batch: cj.usize_or("batch", 0),
                    block: cj.usize_or("block", 0),
                    num_classes: cj.usize_or("num_classes", 0),
                    patch_dim: cj.usize_or("patch_dim", 0),
                    lr: cj.f64_or("lr", 0.0),
                    param_count: cj.usize_or("param_count", 0),
                    paper_equiv: cj.str_or("paper_equiv", ""),
                    params,
                    masks,
                    mlp_weights,
                },
            );
        }

        let mut entries = BTreeMap::new();
        for ej in j.req("entries").as_arr().context("entries")? {
            let inputs = ej
                .req("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|i| IoSpec {
                    name: i.str_or("name", ""),
                    shape: shape_of(i.req("shape")),
                    dtype: i.str_or("dtype", "float32"),
                })
                .collect();
            let outputs = ej
                .req("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(|o| o.as_str().unwrap_or("").to_string())
                .collect();
            let name = ej.str_or("name", "");
            entries.insert(
                name.clone(),
                EntryInfo {
                    name,
                    file: ej.str_or("file", ""),
                    config: ej.get("config").and_then(|c| c.as_str()).map(String::from),
                    kind: ej.str_or("kind", ""),
                    inputs,
                    outputs,
                },
            );
        }
        let adam = j.req("adam");
        let manifest = Manifest {
            configs,
            entries,
            adam: (
                adam.f64_or("b1", 0.9),
                adam.f64_or("b2", 0.95),
                adam.f64_or("eps", 1e-8),
            ),
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        for e in self.entries.values() {
            if let Some(cfg) = &e.config {
                if !self.configs.contains_key(cfg) {
                    bail!("entry {} references unknown config {cfg}", e.name);
                }
            }
            if e.inputs.is_empty() || e.outputs.is_empty() {
                bail!("entry {} has empty IO", e.name);
            }
        }
        for c in self.configs.values() {
            for (name, shape) in &c.masks {
                let (k, n) = match c.param_shape(name) {
                    Some([k, n]) => (*k, *n),
                    other => bail!("mask {name} has non-2D param shape {other:?}"),
                };
                if shape[0] * c.block != k || shape[1] * c.block != n {
                    bail!("mask {name} shape {shape:?} inconsistent with block {}", c.block);
                }
            }
        }
        Ok(())
    }

    pub fn entry(&self, name: &str) -> Result<&EntryInfo> {
        self.entries
            .get(name)
            .with_context(|| format!("no AOT entry {name:?} (have: {:?})", self.entries.keys()))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigInfo> {
        self.configs
            .get(name)
            .with_context(|| format!("no config {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1,
      "adam": {"b1": 0.9, "b2": 0.95, "eps": 1e-8},
      "configs": {
        "t": {"name": "t", "kind": "gpt2", "vocab": 8, "emb": 4, "ffn": 8,
              "layers": 1, "heads": 1, "head_dim": 4, "seq": 4, "batch": 1,
              "block": 2, "num_classes": 0, "patch_dim": 0, "lr": 0.001,
              "param_count": 10, "paper_equiv": "GPT2",
              "params": [{"name": "layer0.mlp.w1", "shape": [4, 8]}],
              "masks": [{"name": "layer0.mlp.w1", "shape": [2, 4]}],
              "mlp_weights": ["layer0.mlp.w1"]}
      },
      "entries": [
        {"name": "t_eval", "file": "t_eval.hlo.txt", "config": "t",
         "kind": "eval_loss",
         "inputs": [{"name": "x", "shape": [1, 4], "dtype": "int32"}],
         "outputs": ["loss"]}
      ]
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.configs.len(), 1);
        let c = m.config("t").unwrap();
        assert_eq!(c.block, 2);
        assert_eq!(c.param_shape("layer0.mlp.w1"), Some(&[4usize, 8][..]));
        let e = m.entry("t_eval").unwrap();
        assert_eq!(e.inputs[0].dtype, "int32");
        assert_eq!(m.adam.0, 0.9);
    }

    #[test]
    fn rejects_inconsistent_mask_shape() {
        let bad = MINI.replace("\"shape\": [2, 4]", "\"shape\": [3, 4]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn layer_parse() {
        assert_eq!(ConfigInfo::layer_of("layer3.mlp.w1"), Some(3));
        assert_eq!(ConfigInfo::layer_of("tok_emb"), None);
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // exercised against the actual artifacts when present
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.contains_key("micro_train_step"));
            let c = m.config("micro").unwrap();
            assert_eq!(c.kind, "gpt2");
            assert_eq!(c.params.len(), c.params.iter().map(|_| 1).sum::<usize>());
        }
    }
}
