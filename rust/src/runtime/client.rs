//! The PJRT execution wrapper (compiled with the `pjrt` feature).
//!
//! One `Runtime` owns a CPU `PjRtClient`, the parsed manifest, and a cache
//! of compiled executables (each HLO module is compiled exactly once per
//! process). Calls are validated against the manifest's flat positional
//! ABI before they reach PJRT, so shape bugs surface as readable errors
//! instead of XLA aborts. The value type ([`HostValue`]) lives in
//! [`crate::runtime::hostvalue`] so the rest of the crate is independent
//! of the `xla` dependency.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::runtime::hostvalue::HostValue;
use crate::runtime::manifest::{EntryInfo, Manifest};

fn to_literal(v: &HostValue) -> Result<xla::Literal> {
    let (ty, dims, bytes): (xla::ElementType, &[usize], &[u8]) = match v {
        HostValue::F32 { shape, data } => (
            xla::ElementType::F32,
            shape,
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) },
        ),
        HostValue::I32 { shape, data } => (
            xla::ElementType::S32,
            shape,
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) },
        ),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
        .map_err(|e| anyhow::anyhow!("literal create: {e}"))
}

fn from_literal(lit: &xla::Literal) -> Result<HostValue> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostValue::F32 {
            shape: dims,
            data: lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?,
        }),
        xla::ElementType::S32 => Ok(HostValue::I32 {
            shape: dims,
            data: lit
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?,
        }),
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// Compiled-executable cache + manifest-validated dispatch.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT C API is thread-safe for client/executable use; the
// wrapper types only miss the auto traits because they hold raw pointers.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifact location relative to the crate root.
    pub fn open_default() -> Result<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::open(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) executable for an entry.
    pub fn load(&self, entry: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(entry) {
            return Ok(e.clone());
        }
        let info = self.manifest.entry(entry)?;
        let path = self.dir.join(&info.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {entry}: {e}"))?;
        crate::log_info!(
            "runtime",
            "compiled {entry} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let arc = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(entry.to_string(), arc.clone());
        Ok(arc)
    }

    fn validate_inputs(&self, info: &EntryInfo, inputs: &[HostValue]) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                info.name,
                info.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&info.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "{} input #{i} ({}): shape {:?} != manifest {:?}",
                    info.name,
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "{} input #{i} ({}): dtype {} != manifest {}",
                    info.name,
                    spec.name,
                    v.dtype(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an entry point with manifest validation. Returns the
    /// decomposed output tuple in manifest order.
    pub fn execute(&self, entry: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        let info = self.manifest.entry(entry)?.clone();
        self.validate_inputs(&info, inputs)?;
        let exe = self.load(entry)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {entry}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {entry} output: {e}"))?;
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing {entry} tuple: {e}"))?;
        if parts.len() != info.outputs.len() {
            bail!(
                "{entry}: manifest says {} outputs, executable returned {}",
                info.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(from_literal).collect()
    }

    /// Map output name → value for an executed entry.
    pub fn execute_named(
        &self,
        entry: &str,
        inputs: &[HostValue],
    ) -> Result<std::collections::BTreeMap<String, HostValue>> {
        let info = self.manifest.entry(entry)?.clone();
        let out = self.execute(entry, inputs)?;
        Ok(info.outputs.iter().cloned().zip(out).collect())
    }
}
