//! The PJRT execution wrapper.
//!
//! One `Runtime` owns a CPU `PjRtClient`, the parsed manifest, and a cache
//! of compiled executables (each HLO module is compiled exactly once per
//! process). Calls are validated against the manifest's flat positional
//! ABI before they reach PJRT, so shape bugs surface as readable errors
//! instead of XLA aborts.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{EntryInfo, Manifest};
use crate::tensor::Tensor;

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn from_tensor(t: &Tensor) -> HostValue {
        HostValue::F32 {
            shape: t.shape().to_vec(),
            data: t.data().to_vec(),
        }
    }

    pub fn tensor(t: Tensor) -> HostValue {
        HostValue::F32 {
            shape: t.shape().to_vec(),
            data: t.into_data(),
        }
    }

    pub fn scalar_i32(v: i32) -> HostValue {
        HostValue::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn scalar_f32(v: f32) -> HostValue {
        HostValue::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn i32s(shape: &[usize], data: Vec<i32>) -> HostValue {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } | HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostValue::F32 { .. } => "float32",
            HostValue::I32 { .. } => "int32",
        }
    }

    /// Unwrap as an f32 tensor.
    pub fn into_tensor(self) -> Result<Tensor> {
        match self {
            HostValue::F32 { shape, data } => Ok(Tensor::new(&shape, data)),
            HostValue::I32 { .. } => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 value"),
        }
    }

    /// Scalar f32 (loss values etc.).
    pub fn scalar(&self) -> Result<f32> {
        match self {
            HostValue::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => bail!("expected scalar f32, got {:?} {:?}", other.dtype(), other.shape()),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], &[u8]) = match self {
            HostValue::F32 { shape, data } => (
                xla::ElementType::F32,
                shape,
                unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                },
            ),
            HostValue::I32 { shape, data } => (
                xla::ElementType::S32,
                shape,
                unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                },
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
            .map_err(|e| anyhow::anyhow!("literal create: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostValue> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostValue::F32 {
                shape: dims,
                data: lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?,
            }),
            xla::ElementType::S32 => Ok(HostValue::I32 {
                shape: dims,
                data: lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Compiled-executable cache + manifest-validated dispatch.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT C API is thread-safe for client/executable use; the
// wrapper types only miss the auto traits because they hold raw pointers.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifact location relative to the crate root.
    pub fn open_default() -> Result<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::open(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) executable for an entry.
    pub fn load(&self, entry: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(entry) {
            return Ok(e.clone());
        }
        let info = self.manifest.entry(entry)?;
        let path = self.dir.join(&info.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {entry}: {e}"))?;
        crate::log_info!(
            "runtime",
            "compiled {entry} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let arc = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(entry.to_string(), arc.clone());
        Ok(arc)
    }

    fn validate_inputs(&self, info: &EntryInfo, inputs: &[HostValue]) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                info.name,
                info.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&info.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() {
                bail!(
                    "{} input #{i} ({}): shape {:?} != manifest {:?}",
                    info.name,
                    spec.name,
                    v.shape(),
                    spec.shape
                );
            }
            if v.dtype() != spec.dtype {
                bail!(
                    "{} input #{i} ({}): dtype {} != manifest {}",
                    info.name,
                    spec.name,
                    v.dtype(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }

    /// Execute an entry point with manifest validation. Returns the
    /// decomposed output tuple in manifest order.
    pub fn execute(&self, entry: &str, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        let info = self.manifest.entry(entry)?.clone();
        self.validate_inputs(&info, inputs)?;
        let exe = self.load(entry)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {entry}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {entry} output: {e}"))?;
        let parts = root
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing {entry} tuple: {e}"))?;
        if parts.len() != info.outputs.len() {
            bail!(
                "{entry}: manifest says {} outputs, executable returned {}",
                info.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(HostValue::from_literal).collect()
    }

    /// Map output name → value for an executed entry.
    pub fn execute_named(
        &self,
        entry: &str,
        inputs: &[HostValue],
    ) -> Result<std::collections::BTreeMap<String, HostValue>> {
        let info = self.manifest.entry(entry)?.clone();
        let out = self.execute(entry, inputs)?;
        Ok(info.outputs.iter().cloned().zip(out).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostvalue_roundtrip_shapes() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = HostValue::from_tensor(&t);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.dtype(), "float32");
        assert_eq!(v.into_tensor().unwrap(), t);
        let s = HostValue::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn scalar_accessor_rejects_nonscalar() {
        let v = HostValue::F32 {
            shape: vec![2],
            data: vec![1.0, 2.0],
        };
        assert!(v.scalar().is_err());
    }
}
