//! Synthetic pretraining corpus: a first-order Markov chain whose unigram
//! marginal is Zipf-distributed (natural-language-like token frequencies)
//! and whose transition structure carries learnable bigram signal.
//!
//! Entropy is controllable via `peakedness`: each token's outgoing
//! distribution concentrates mass on a few successor tokens. A model that
//! learns the transitions reaches a perplexity well below vocab size, so
//! the dense-vs-sparse perplexity gaps of Tables 2/4/5/6 are measurable.

use crate::util::rng::{Rng, Zipf};

/// One LM training batch in the AOT ABI layout.
#[derive(Clone, Debug)]
pub struct LmBatch {
    /// (batch * seq) current tokens, row-major.
    pub tokens: Vec<i32>,
    /// (batch * seq) next tokens.
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Deterministic synthetic corpus.
pub struct Corpus {
    vocab: usize,
    /// Per-token successor tables: (successors, cdf) — sparse transitions.
    succ: Vec<Vec<u32>>,
    cdf: Vec<Vec<f64>>,
    rng: Rng,
    state: usize,
}

impl Corpus {
    /// `branching` successors per token (smaller = lower entropy);
    /// successor identities and weights are Zipf-skewed.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> Corpus {
        assert!(vocab >= 4 && branching >= 2);
        let mut rng = Rng::new(seed);
        let zipf = Zipf::new(vocab, 1.05);
        let mut succ = Vec::with_capacity(vocab);
        let mut cdf = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut outs = Vec::with_capacity(branching);
            while outs.len() < branching {
                let t = zipf.sample(&mut rng) as u32;
                if !outs.contains(&t) {
                    outs.push(t);
                }
            }
            // geometric-ish weights over successors
            let mut acc = 0.0;
            let mut c = Vec::with_capacity(branching);
            for j in 0..branching {
                acc += 1.0 / (1.0 + j as f64).powf(1.5);
                c.push(acc);
            }
            for v in &mut c {
                *v /= acc;
            }
            succ.push(outs);
            cdf.push(c);
        }
        Corpus {
            vocab,
            succ,
            cdf,
            rng,
            state: 0,
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn next_token(&mut self) -> u32 {
        let u = self.rng.f64();
        let row = &self.cdf[self.state];
        let j = row.partition_point(|&c| c < u).min(row.len() - 1);
        let t = self.succ[self.state][j];
        self.state = t as usize;
        t
    }

    /// Generate a `(batch, seq)` training batch with next-token targets.
    pub fn batch(&mut self, batch: usize, seq: usize) -> LmBatch {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            // restart each row from a random state for i.i.d.-ish rows
            self.state = self.rng.below(self.vocab);
            let mut prev = self.next_token();
            for _ in 0..seq {
                let next = self.next_token();
                tokens.push(prev as i32);
                targets.push(next as i32);
                prev = next;
            }
        }
        LmBatch {
            tokens,
            targets,
            batch,
            seq,
        }
    }

    /// A fixed held-out set, deterministic across runs (same seed →
    /// same eval batches regardless of how much training data was drawn).
    pub fn eval_batches(vocab: usize, branching: usize, seed: u64, n: usize, batch: usize, seq: usize) -> Vec<LmBatch> {
        let mut c = Corpus::new(vocab, branching, seed ^ 0xEEEE_EEEE);
        (0..n).map(|_| c.batch(batch, seq)).collect()
    }

    /// Empirical bigram entropy (bits) of the chain — the floor for model
    /// cross-entropy; used in tests to sanity-check learnability.
    pub fn transition_entropy_bits(&self) -> f64 {
        let mut h = 0.0;
        for row in &self.cdf {
            let mut prev = 0.0;
            for &c in row {
                let p = c - prev;
                if p > 0.0 {
                    h -= p * p.log2();
                }
                prev = c;
            }
        }
        h / self.cdf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Corpus::new(256, 8, 42);
        let mut b = Corpus::new(256, 8, 42);
        for _ in 0..1000 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }

    #[test]
    fn batch_layout_and_shift() {
        let mut c = Corpus::new(128, 4, 1);
        let b = c.batch(3, 16);
        assert_eq!(b.tokens.len(), 48);
        assert_eq!(b.targets.len(), 48);
        // within a row, targets are the next tokens
        for row in 0..3 {
            for i in 0..15 {
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
        assert!(b.tokens.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn entropy_well_below_uniform() {
        let c = Corpus::new(512, 8, 3);
        let h = c.transition_entropy_bits();
        // uniform over 512 would be 9 bits; branching 8 caps at 3 bits
        assert!(h < 3.01, "entropy {h}");
        assert!(h > 1.0, "too deterministic to be interesting: {h}");
    }

    #[test]
    fn eval_batches_stable() {
        let a = Corpus::eval_batches(128, 4, 9, 2, 2, 8);
        let b = Corpus::eval_batches(128, 4, 9, 2, 2, 8);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_eq!(a[1].targets, b[1].targets);
    }
}
