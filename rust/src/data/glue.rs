//! Synthetic GLUE-like benchmark (Table 1 substitution).
//!
//! Five binary sequence-classification tasks named after the GLUE subset
//! the paper uses. Each task generates `(seq, feat)` float sequences whose
//! label depends on a task-specific linear-temporal rule, with a per-task
//! noise level chosen so the *difficulty spread* resembles the paper's
//! (WNLI near-chance, SST-2 easy, CoLA in between — compare Table 1's
//! dense row). The fine-tuning protocol, and the claim under test
//! (robustness of accuracy to sparsity level and block size), carry over
//! unchanged.

use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlueTask {
    CoLA,
    Sst2,
    Mrpc,
    Rte,
    Wnli,
}

impl GlueTask {
    pub fn all() -> [GlueTask; 5] {
        [
            GlueTask::CoLA,
            GlueTask::Sst2,
            GlueTask::Mrpc,
            GlueTask::Rte,
            GlueTask::Wnli,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::CoLA => "CoLA",
            GlueTask::Sst2 => "SST-2",
            GlueTask::Mrpc => "MRPC",
            GlueTask::Rte => "RTE",
            GlueTask::Wnli => "WNLI",
        }
    }

    /// Metric reported in Table 1.
    pub fn metric(&self) -> &'static str {
        match self {
            GlueTask::CoLA => "Matt. Corr",
            GlueTask::Mrpc => "ACC/F1",
            _ => "ACC",
        }
    }

    /// Label-noise rate — sets the achievable ceiling per task.
    fn noise(&self) -> f64 {
        match self {
            GlueTask::CoLA => 0.20,
            GlueTask::Sst2 => 0.05,
            GlueTask::Mrpc => 0.15,
            GlueTask::Rte => 0.25,
            GlueTask::Wnli => 0.48, // near-chance, like the paper's 56.34
        }
    }

    fn seed_tag(&self) -> u64 {
        match self {
            GlueTask::CoLA => 0xC01A,
            GlueTask::Sst2 => 0x5572,
            GlueTask::Mrpc => 0x3390,
            GlueTask::Rte => 0x0973,
            GlueTask::Wnli => 0x3311,
        }
    }
}

/// One classification batch in the AOT ABI layout.
#[derive(Clone, Debug)]
pub struct GlueBatch {
    /// (batch * seq * feat) features, row-major.
    pub features: Vec<f32>,
    /// (batch) labels in {0, 1}.
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    pub feat: usize,
}

/// Deterministic task generator.
pub struct GlueGen {
    task: GlueTask,
    seq: usize,
    feat: usize,
    /// Hidden direction defining the decision rule.
    w: Vec<f32>,
    rng: Rng,
}

impl GlueGen {
    pub fn new(task: GlueTask, seq: usize, feat: usize, seed: u64) -> GlueGen {
        // the hidden decision rule `w` is a function of (task, seed) ONLY —
        // train and eval streams must share it (they differ in the example
        // stream, reseeded via `reseed_stream`)
        let mut wrng = Rng::new(seed ^ task.seed_tag());
        let w = wrng.normal_vec(feat, 1.0);
        let rng = Rng::new(seed ^ task.seed_tag() ^ 0x5EED_0001);
        GlueGen {
            task,
            seq,
            feat,
            w,
            rng,
        }
    }

    /// Switch to an independent example stream (same task rule).
    pub fn reseed_stream(&mut self, tag: u64) {
        self.rng = Rng::new(tag ^ 0xE7A1_0000_0000);
    }

    pub fn task(&self) -> GlueTask {
        self.task
    }

    /// Draw one example: features + true label (possibly noise-flipped).
    fn example(&mut self) -> (Vec<f32>, i32) {
        let mut x = self.rng.normal_vec(self.seq * self.feat, 1.0);
        // the signal lives in the mean projection onto w, modulated by a
        // simple temporal pattern (first half vs second half contrast)
        let label = self.rng.below(2) as i32;
        let sign = if label == 1 { 1.0 } else { -1.0 };
        let half = self.seq / 2;
        for s in 0..self.seq {
            let amp = if s < half { 1.0 } else { -1.0 };
            for f in 0..self.feat {
                x[s * self.feat + f] += sign * amp * self.w[f] / (self.feat as f32).sqrt() * 3.0;
            }
        }
        let noisy = if self.rng.f64() < self.task.noise() {
            1 - label
        } else {
            label
        };
        (x, noisy)
    }

    pub fn batch(&mut self, batch: usize) -> GlueBatch {
        let mut features = Vec::with_capacity(batch * self.seq * self.feat);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let (x, y) = self.example();
            features.extend_from_slice(&x);
            labels.push(y);
        }
        GlueBatch {
            features,
            labels,
            batch,
            seq: self.seq,
            feat: self.feat,
        }
    }

    /// Fixed held-out set for scoring — same task rule, independent stream.
    pub fn eval_set(task: GlueTask, seq: usize, feat: usize, seed: u64, n: usize, batch: usize) -> Vec<GlueBatch> {
        let mut g = GlueGen::new(task, seq, feat, seed);
        g.reseed_stream(seed);
        (0..n).map(|_| g.batch(batch)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_balanced_and_deterministic() {
        let mut g = GlueGen::new(GlueTask::Sst2, 8, 16, 1);
        let b = g.batch(200);
        let ones: i32 = b.labels.iter().sum();
        assert!((60..140).contains(&ones), "unbalanced: {ones}");
        let mut g2 = GlueGen::new(GlueTask::Sst2, 8, 16, 1);
        let b2 = g2.batch(200);
        assert_eq!(b.labels, b2.labels);
        assert_eq!(b.features, b2.features);
    }

    #[test]
    fn linear_probe_separates_sst2_but_not_wnli() {
        // score examples by the hidden rule itself: SST-2 should be highly
        // separable, WNLI near chance (by construction of the noise rates)
        for (task, lo, hi) in [(GlueTask::Sst2, 0.85, 1.0), (GlueTask::Wnli, 0.40, 0.65)] {
            let mut g = GlueGen::new(task, 8, 16, 3);
            let w = g.w.clone();
            let b = g.batch(400);
            let mut correct = 0;
            for i in 0..400 {
                let x = &b.features[i * 8 * 16..(i + 1) * 8 * 16];
                let mut first = 0.0;
                let mut second = 0.0;
                for s in 0..8 {
                    let proj: f32 = (0..16).map(|f| x[s * 16 + f] * w[f]).sum();
                    if s < 4 {
                        first += proj;
                    } else {
                        second += proj;
                    }
                }
                let pred = if first - second > 0.0 { 1 } else { 0 };
                if pred == b.labels[i] {
                    correct += 1;
                }
            }
            let acc = correct as f64 / 400.0;
            assert!(
                (lo..=hi).contains(&acc),
                "{}: probe acc {acc} outside [{lo},{hi}]",
                task.name()
            );
        }
    }

    #[test]
    fn task_metadata() {
        assert_eq!(GlueTask::all().len(), 5);
        assert_eq!(GlueTask::CoLA.metric(), "Matt. Corr");
        assert_eq!(GlueTask::Mrpc.metric(), "ACC/F1");
    }
}

#[cfg(test)]
mod eval_consistency {
    use super::*;

    /// Regression test for the eval-mismatch bug: train and eval streams
    /// must share the SAME hidden rule (w), differing only in examples.
    #[test]
    fn eval_set_shares_task_rule_with_training() {
        let (seq, feat, seed) = (8, 16, 42);
        let train_gen = GlueGen::new(GlueTask::Sst2, seq, feat, seed);
        let w = train_gen.w.clone();
        // score the eval set with the TRAINING generator's rule
        let eval = GlueGen::eval_set(GlueTask::Sst2, seq, feat, seed, 4, 64);
        let mut correct = 0;
        let mut total = 0;
        for b in &eval {
            for i in 0..b.batch {
                let x = &b.features[i * seq * feat..(i + 1) * seq * feat];
                let mut first = 0.0;
                let mut second = 0.0;
                for s in 0..seq {
                    let proj: f32 = (0..feat).map(|f| x[s * feat + f] * w[f]).sum();
                    if s < seq / 2 {
                        first += proj;
                    } else {
                        second += proj;
                    }
                }
                let pred = if first - second > 0.0 { 1 } else { 0 };
                if pred == b.labels[i] {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.85, "train rule must classify eval set: acc {acc}");
        // and the eval stream is genuinely different data
        let mut train_gen2 = GlueGen::new(GlueTask::Sst2, seq, feat, seed);
        let tb = train_gen2.batch(64);
        assert_ne!(tb.features, eval[0].features);
    }
}
