//! Synthetic data substrates (DESIGN.md §6 substitutions).
//!
//! The paper trains on OpenWebText, GLUE and CIFAR-10 — none of which are
//! available in this offline environment. Each generator below preserves
//! the property the corresponding experiment actually measures:
//!
//! * [`corpus`] — a Markov-chain language with Zipf-distributed unigram
//!   frequencies and controllable entropy: learnable structure so
//!   perplexity *differences between sparsification settings* (Tables 2,
//!   4–6) are meaningful.
//! * [`glue`] — five binary sequence-classification tasks with a spread of
//!   difficulty and the paper's metric types (Matthews corr, accuracy,
//!   acc/F1) for the Table 1 fine-tuning protocol.
//! * [`cifar`] — a 10-class procedural image set (class-dependent spatial
//!   frequency patterns + noise), pre-patchified for the ViT twin
//!   (Table 3, Fig. 9).

pub mod cifar;
pub mod corpus;
pub mod glue;

pub use cifar::CifarSim;
pub use corpus::{Corpus, LmBatch};
pub use glue::{GlueTask, GlueBatch};
