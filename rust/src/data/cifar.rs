//! Synthetic CIFAR-10 stand-in for the ViT experiments (Table 3, Fig. 9).
//!
//! Ten classes of procedurally generated 32×32×3 images: each class has a
//! characteristic 2-D spatial frequency + color phase signature with
//! additive noise, so a patch-based Transformer must integrate spatial
//! structure to classify — the same inductive demand CIFAR places on a
//! ViT, at a difficulty where a small twin can reach high accuracy.
//!
//! Images are emitted pre-patchified (`npatch × patch_dim`), matching the
//! `vit-sim` AOT ABI (8×8 patches → 16 patches × 192 features).

use crate::util::rng::Rng;

pub const IMG: usize = 32;
pub const PATCH: usize = 8;
pub const NPATCH: usize = (IMG / PATCH) * (IMG / PATCH); // 16
pub const PATCH_DIM: usize = PATCH * PATCH * 3; // 192
pub const CLASSES: usize = 10;

/// One classification batch in the AOT ABI layout.
#[derive(Clone, Debug)]
pub struct VitBatch {
    /// (batch * NPATCH * PATCH_DIM) features.
    pub patches: Vec<f32>,
    /// (batch) labels in 0..10.
    pub labels: Vec<i32>,
    pub batch: usize,
}

pub struct CifarSim {
    rng: Rng,
    noise: f32,
}

impl CifarSim {
    pub fn new(seed: u64, noise: f32) -> CifarSim {
        CifarSim {
            rng: Rng::new(seed),
            noise,
        }
    }

    /// Class signature at pixel (x, y, channel).
    fn signal(class: usize, x: usize, y: usize, c: usize) -> f32 {
        let fx = 1.0 + (class % 4) as f32;
        let fy = 1.0 + (class / 4) as f32;
        let phase = class as f32 * 0.7 + c as f32 * 2.1;
        let (xf, yf) = (x as f32 / IMG as f32, y as f32 / IMG as f32);
        ((2.0 * std::f32::consts::PI * (fx * xf + fy * yf)) + phase).sin()
    }

    /// Generate one image as patches.
    fn image(&mut self, class: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; NPATCH * PATCH_DIM];
        let grid = IMG / PATCH;
        for py in 0..grid {
            for px in 0..grid {
                let p = py * grid + px;
                for iy in 0..PATCH {
                    for ix in 0..PATCH {
                        for c in 0..3 {
                            let x = px * PATCH + ix;
                            let y = py * PATCH + iy;
                            let v = Self::signal(class, x, y, c)
                                + self.noise * self.rng.normal();
                            out[p * PATCH_DIM + (iy * PATCH + ix) * 3 + c] = v;
                        }
                    }
                }
            }
        }
        out
    }

    pub fn batch(&mut self, batch: usize) -> VitBatch {
        let mut patches = Vec::with_capacity(batch * NPATCH * PATCH_DIM);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let class = self.rng.below(CLASSES);
            patches.extend_from_slice(&self.image(class));
            labels.push(class as i32);
        }
        VitBatch {
            patches,
            labels,
            batch,
        }
    }

    pub fn eval_set(seed: u64, noise: f32, n: usize, batch: usize) -> Vec<VitBatch> {
        let mut g = CifarSim::new(seed ^ 0xC1FA, noise);
        (0..n).map(|_| g.batch(batch)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let mut a = CifarSim::new(5, 0.5);
        let ba = a.batch(4);
        assert_eq!(ba.patches.len(), 4 * NPATCH * PATCH_DIM);
        assert!(ba.labels.iter().all(|&l| (0..10).contains(&l)));
        let mut b = CifarSim::new(5, 0.5);
        assert_eq!(b.batch(4).patches, ba.patches);
    }

    #[test]
    fn classes_are_separable_by_template_matching() {
        let mut g = CifarSim::new(7, 0.8);
        // nearest-template classification should beat chance comfortably
        let mut correct = 0;
        let total = 100;
        for _ in 0..total {
            let class = g.rng.below(CLASSES);
            let img = g.image(class);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for cand in 0..CLASSES {
                let mut score = 0.0f32;
                let grid = IMG / PATCH;
                for py in 0..grid {
                    for px in 0..grid {
                        let p = py * grid + px;
                        for iy in 0..PATCH {
                            for ix in 0..PATCH {
                                for c in 0..3 {
                                    let x = px * PATCH + ix;
                                    let y = py * PATCH + iy;
                                    score += img[p * PATCH_DIM + (iy * PATCH + ix) * 3 + c]
                                        * CifarSim::signal(cand, x, y, c);
                                }
                            }
                        }
                    }
                }
                if score > best.0 {
                    best = (score, cand);
                }
            }
            if best.1 == class {
                correct += 1;
            }
        }
        assert!(correct > 80, "template acc {correct}/100");
    }
}
