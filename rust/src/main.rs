//! `blast` — the L3 coordinator binary.
//!
//! Subcommands:
//!
//! * `blast info` — show the artifact manifest (configs, entries).
//! * `blast train --config gpt2s-sim --steps 200 [--smax 0.8
//!   --backend native|aot ...]` — pretrain a twin with blocked
//!   prune-and-grow; optionally save a checkpoint. The default `native`
//!   backend runs forward + backward + Adam on the packed kernel stack
//!   (no artifacts needed); `aot` drives the PJRT `train_step`
//!   executables. `--guard` (plus `--guard-*` overrides) arms the
//!   self-healing ladder: anomaly skip/clip, divergence rollback to the
//!   last verified autosave, mask-update probe + revert.
//! * `blast serve [--sparsity 0.9 --block 128 --batched false --kv-page 64
//!   --kv-pool-pages 0 --prefix-cache false ...]` — run the
//!   continuous-batching inference coordinator over the native sparse
//!   engine with a synthetic client load, printing latency/throughput
//!   metrics. Decode rounds are batched (`Engine::decode_batch`) unless
//!   `--batched false` selects the sequential GEMV baseline; KV is paged
//!   (`--kv-page` positions per page) from a shared pool
//!   (`--kv-pool-pages`, 0 = unbounded) that admission is gated on.
//!   Prompt prefixes landing on full pages are deduplicated copy-on-write
//!   across sessions unless `--prefix-cache false` restores the unshared
//!   pool byte-for-byte.
//! * `blast exp <kernels|serve|attention|pretrain|fig4..fig11|tab1..tab6|all>`
//!   — regenerate a paper table/figure or an A/B harness (DESIGN.md §5);
//!   `kernels`, `serve`, `attention` and `pretrain` write the
//!   BENCH_*.json perf-trajectory files. The pretraining families
//!   (tab2/fig8/tab4–6/fig10–11) run on the native backend by default and
//!   accept `--backend aot`.
//!
//! Python never runs on the request path; `make artifacts` is only needed
//! for the optional AOT backend and the classifier experiments.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use blast::coordinator::{BatcherConfig, CompletionWait, Coordinator, Request};
use blast::eval;
use blast::model::engine::{AttnOptions, Engine, MlpMode};
use blast::model::params::ParamStore;
use blast::runtime::Runtime;
use blast::train::pretrain::{PretrainOptions, Trainer};
use blast::train::GuardConfig;
use blast::util::cli::Args;
use blast::util::faults::Faults;

/// `--faults site:prob:seed[:value],…` wins over the `BLAST_FAULTS`
/// environment variable; neither present → injection compiled out of the
/// hot path (a single null check).
fn faults_from_args(args: &Args) -> Result<Faults> {
    match args.get("faults") {
        Some(spec) => Faults::parse(spec),
        None => Faults::from_env(),
    }
}

/// `--guard` (or any `--guard-*` threshold override) arms the
/// self-healing training ladder; with none present `run_train` takes the
/// exact pre-guard path, bit-identical to previous releases.
fn guard_from_args(args: &Args) -> Option<GuardConfig> {
    const KEYS: [&str; 12] = [
        "guard-clip",
        "guard-explode",
        "guard-spike",
        "guard-ewma",
        "guard-div-tol",
        "guard-div-steps",
        "guard-max-skips",
        "guard-backoff-ms",
        "guard-max-rollbacks",
        "guard-mask-budget",
        "guard-cooldown",
        "guard-probe-batches",
    ];
    if !args.get_bool("guard") && KEYS.iter().all(|k| args.get(k).is_none()) {
        return None;
    }
    let d = GuardConfig::default();
    Some(GuardConfig {
        clip_norm: args.get_f64("guard-clip", d.clip_norm),
        explode_norm: args.get_f64("guard-explode", d.explode_norm),
        spike_mul: args.get_f64("guard-spike", d.spike_mul),
        ewma_alpha: args.get_f64("guard-ewma", d.ewma_alpha),
        div_tol: args.get_f64("guard-div-tol", d.div_tol),
        div_steps: args.get_usize("guard-div-steps", d.div_steps),
        max_skips: args.get_usize("guard-max-skips", d.max_skips),
        backoff_ms: args.get_usize("guard-backoff-ms", d.backoff_ms as usize) as u64,
        max_rollbacks: args.get_usize("guard-max-rollbacks", d.max_rollbacks),
        mask_budget: args.get_f64("guard-mask-budget", d.mask_budget),
        cooldown_updates: args.get_usize("guard-cooldown", d.cooldown_updates),
        probe_batches: args.get_usize("guard-probe-batches", d.probe_batches),
    })
}

fn main() {
    blast::util::logging::init();
    let args = Args::parse();
    // `--no-simd` forces the scalar kernel arm (same effect as
    // BLAST_SIMD=off) — set before any kernel work so the choice is
    // process-wide and bit-stable.
    blast::kernels::simd::set_simd_enabled(!args.get_bool("no-simd"));
    let cmd = args.pos(0).unwrap_or("help").to_string();
    let code = match cmd.as_str() {
        "info" => run_info(&args),
        "train" => run_train(&args),
        "serve" => run_serve(&args),
        "exp" => {
            let id = args.pos(1).unwrap_or("all").to_string();
            println!("kernel isa: {}", blast::kernels::simd::dispatch().isa.name());
            eval::run(&id, &args)
        }
        _ => {
            print_help();
            Ok(())
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

fn print_help() {
    println!(
        "blast — BLock Sparse Transformers (paper reproduction)\n\n\
         USAGE:\n  blast info\n  blast train --config <name> [--steps N --smax S --step-size K \\\n\
         \x20            --decay D --dense-right L --block-mult M --save ckpt.bin \\\n\
         \x20            --save-ckpt full.blst --resume full.blst \\\n\
         \x20            --ckpt-dir dir --ckpt-every N --ckpt-keep K \\\n\
         \x20            --guard [--guard-clip C --guard-explode E --guard-spike M \\\n\
         \x20            --guard-ewma A --guard-div-tol T --guard-div-steps K \\\n\
         \x20            --guard-max-skips K --guard-backoff-ms MS --guard-max-rollbacks K \\\n\
         \x20            --guard-mask-budget B --guard-cooldown K --guard-probe-batches N] \\\n\
         \x20            --backend native|aot]\n\
         \x20 blast serve [--sparsity S --block B --requests N --max-batch K --batched false \\\n\
         \x20             --kv-page P --kv-pool-pages M --prefix-cache false --deadline-ms D \\\n\
         \x20             --attn-threshold TAU --replicas R --fleet-seed S --stall-ms T \\\n\
         \x20             --faults site:prob:seed[,..] --no-simd]\n\
         \x20 blast exp <id> [--steps N --quick --backend native|aot ...]   ids: {:?} or 'all'\n\n\
         Fault sites for --faults / BLAST_FAULTS: decode_round_panic,\n\
         decode_round_error, prefill_error, kv_pool_exhausted,\n\
         decode_stall_ms, ckpt_torn_write, scheduler_panic,\n\
         replica_crash, replica_stall_ms, heartbeat_drop, grad_nan,\n\
         grad_explode, loss_spike_mul, mask_corrupt (the four training\n\
         sites inject only on the guarded path).\n\n\
         `blast train --guard` arms the self-healing ladder: global-norm\n\
         clip, anomaly skip with jittered backoff, divergence rollback to\n\
         the last verified autosave (data order re-forked), and a held-out\n\
         probe that reverts mask updates regressing loss beyond\n\
         --guard-mask-budget. Guards off = bit-identical to previous\n\
         releases.\n\n\
         `--attn-threshold TAU` arms BLASST dynamic attention sparsity:\n\
         k-tiles (prefill) and KV pages (decode) whose score bound falls\n\
         more than TAU below the running row max are skipped. Omitted =\n\
         exact attention, bit-identical to previous releases.\n\n\
         `--replicas R` (R > 1) serves through the replicated fleet tier:\n\
         deterministic least-loaded placement, heartbeat crash/stall\n\
         detection, bitwise-identical in-flight failover, jittered\n\
         restarts. `--replicas 1` (default) is the bare coordinator.\n\n\
         Training and the pretraining experiments run natively by default;\n\
         `--backend aot` and the classifier experiments need `make artifacts`\n\
         plus a `--features pjrt` build.",
        eval::ALL
    );
}

fn run_info(_args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let m = rt.manifest();
    println!("configs:");
    for c in m.configs.values() {
        println!(
            "  {:14} kind={:5} params={:>9} emb={} ffn={} layers={} seq={} batch={} block={} (paper: {})",
            c.name, c.kind, c.param_count, c.emb, c.ffn, c.layers, c.seq, c.batch, c.block, c.paper_equiv
        );
    }
    println!("entries:");
    for e in m.entries.values() {
        println!(
            "  {:35} kind={:16} inputs={:3} outputs={:3} file={}",
            e.name,
            e.kind,
            e.inputs.len(),
            e.outputs.len(),
            e.file
        );
    }
    Ok(())
}

fn run_train(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 200);
    let faults = faults_from_args(args)?;
    // native (packed-kernel fwd+bwd+Adam) is the default; `--backend aot`
    // selects the PJRT executables (pjrt feature + artifacts required)
    let rt = blast::train::pretrain::open_backend_runtime(&args.get_str("backend", "native"))?;
    let mut trainer = if let Some(ckpt) = args.get("resume") {
        // full-state resume: params + Adam moments + masks + corpus
        // position come from the checkpoint, continuing bit-identically
        let t = Trainer::resume_from(Path::new(ckpt))?;
        println!(
            "resumed {} from {ckpt} at iter {} (optimizer step {})",
            t.config().name,
            t.done_iters(),
            t.state().step
        );
        t
    } else {
        let config = args.get_str("config", "gpt2s-sim");
        let opts = PretrainOptions {
            total_iters: steps,
            s_init: args.get_f64("sinit", 0.0),
            s_max: args.get_f64("smax", 0.8),
            decay: args.get_usize("decay", 0),
            step_size: args.get_usize("step-size", 10),
            dense_right: args.get_usize("dense-right", 0),
            dense_left: args.get_usize("dense-left", 0),
            seed: args.get_usize("seed", 0xB1A57) as u64,
            branching: args.get_usize("branching", 8),
            block_mult: args.get_usize("block-mult", 1),
        };
        Trainer::from_backend(rt.as_ref(), &config, opts)?
    };
    // the trainer shares the CLI's injector handle so the exit summary
    // below reflects training-path fires; set before arming the guard —
    // the guard's jitter stream forks off this injector's spec
    trainer.set_faults(faults.clone());
    if let Some(cfg) = guard_from_args(args) {
        trainer.arm_guard(cfg);
        println!(
            "training guard armed: clip={} explode={} spike={} div_tol={}/{} \
             max_skips={} max_rollbacks={} mask_budget={}",
            cfg.clip_norm,
            cfg.explode_norm,
            cfg.spike_mul,
            cfg.div_tol,
            cfg.div_steps,
            cfg.max_skips,
            cfg.max_rollbacks,
            cfg.mask_budget
        );
    }
    if faults.enabled() {
        println!("fault injection active: {}", faults.spec());
    }
    let config = trainer.config().name.clone();
    println!("backend: {}", trainer.backend_name());
    let t0 = std::time::Instant::now();
    match args.get("ckpt-dir") {
        // crash-safe autosaves: atomic writes, CRC-verified on load,
        // newest `--ckpt-keep` retained; `--resume <newest>` continues
        Some(dir) => trainer.run_with_autosave(
            steps,
            Path::new(dir),
            args.get_usize("ckpt-every", 50),
            args.get_usize("ckpt-keep", 3),
            &faults,
        )?,
        None => trainer.run(steps)?,
    }
    let ppl = trainer.eval_perplexity(args.get_usize("eval-batches", 8))?;
    println!(
        "trained {config} for {steps} iters in {:.1}s — final sparsity {:.2}, eval ppl {ppl:.3}",
        t0.elapsed().as_secs_f64(),
        trainer.controller().mean_sparsity()
    );
    if let Some(g) = trainer.guard() {
        println!("guard: {}", g.summary());
        if trainer.data_fork() > 0 {
            println!(
                "data order re-forked {} time(s) by divergence rollback",
                trainer.data_fork()
            );
        }
    }
    // per-site fired/checked accounting, mirroring `blast serve`'s exit
    // line; printed only when armed so plain runs stay byte-identical
    if faults.enabled() {
        println!("fault injector: {}", faults.summary());
    }
    if let Some(path) = args.get("save") {
        trainer.params().save(Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    if let Some(path) = args.get("save-ckpt") {
        trainer.save_checkpoint(Path::new(path))?;
        println!("full training checkpoint (resumable) saved to {path}");
    }
    Ok(())
}

fn run_serve(args: &Args) -> Result<()> {
    use blast::eval::kernel_exps::{fig6_config, fig6_params, random_masks};
    use blast::model::kv::{KvOptions, DEFAULT_KV_PAGE};
    let block = args.get_usize("block", 128);
    let sparsity = args.get_f64("sparsity", 0.9);
    let n_requests = args.get_usize("requests", 24);
    let max_new = args.get_usize("max-new", 16);
    let cfg = fig6_config(block);
    let params = fig6_params(&cfg, 42);
    let masks = if sparsity > 0.0 {
        random_masks(&cfg, sparsity, 43)
    } else {
        Default::default()
    };
    let mode = if args.get_bool("dense") {
        MlpMode::Dense
    } else {
        MlpMode::Sparse
    };
    let batched = args.get_bool_or("batched", true);
    let kv_page = args.get_usize("kv-page", DEFAULT_KV_PAGE);
    // 0 = unbounded (the default): no admission gating on KV memory
    let kv_pool_pages = match args.get_usize("kv-pool-pages", 0) {
        0 => None,
        n => Some(n),
    };
    // default on; `--prefix-cache false` restores the unshared pool
    // byte-for-byte (same serving output, same metrics summary)
    let prefix_cache = args.get_bool_or("prefix-cache", true);
    // BLASST dynamic attention sparsity: off (exact attention) unless a
    // finite τ >= 0 is given; NaN/negative τ panics in the getter and the
    // engine validates again at build time
    let attn = AttnOptions { threshold: args.get_threshold("attn-threshold") };
    let engine = Arc::new(Engine::new_with_opts(
        cfg.clone(),
        &params,
        &masks,
        mode,
        KvOptions { page: kv_page, pool_pages: kv_pool_pages, prefix_cache },
        attn,
    )?);
    println!(
        "serving {} (mode={mode:?}, isa={}, sparsity={sparsity}, block={block}, batched={batched}, \
         kv-page={kv_page}, kv-pool-pages={}, mlp bytes={})",
        cfg.name,
        blast::kernels::simd::dispatch().isa.name(),
        kv_pool_pages.map(|n| n.to_string()).unwrap_or_else(|| "unbounded".into()),
        engine.mlp_weight_bytes()
    );
    if prefix_cache {
        // printed only when sharing is on so the off path stays
        // byte-identical to the pre-sharing coordinator
        println!("kv prefix cache: on (copy-on-write page sharing, --prefix-cache false to disable)");
    }
    if let Some(tau) = attn.threshold {
        // printed only when armed so τ=off output stays byte-identical
        // to the pre-threshold coordinator
        println!("attn threshold: tau={tau} (BLASST dynamic sparsity; omit --attn-threshold for exact attention)");
    }
    let faults = faults_from_args(args)?;
    if faults.enabled() {
        println!("fault injection active: {}", faults.spec());
    }
    // 0 = no deadline: requests wait/decode as long as they need
    let deadline_ms = match args.get_usize("deadline-ms", 0) {
        0 => None,
        ms => Some(ms as u64),
    };
    let batcher = BatcherConfig {
        max_batch: args.get_usize("max-batch", 4),
        max_queue: args.get_usize("max-queue", 64),
        batched,
        ..BatcherConfig::default()
    };
    let replicas = args.get_usize("replicas", 1);
    if replicas > 1 {
        return serve_fleet(
            args, &engine, batcher, faults, replicas, n_requests, max_new, deadline_ms, cfg.vocab,
        );
    }
    let mut coord = Coordinator::start_with_faults(engine, batcher, faults);
    for i in 0..n_requests {
        let len = 8 + (i % 8);
        coord.submit(Request {
            id: i as u64,
            prompt: (0..len).map(|j| ((i * 131 + j * 17) % cfg.vocab) as u32).collect(),
            max_new,
            eos: None,
            deadline_ms,
        })?;
    }
    let mut done = 0;
    while done < n_requests {
        match coord.next_completion(Duration::from_secs(120)) {
            CompletionWait::Ready(c) => {
                done += 1;
                if let Some(e) = c.error {
                    println!("request {} failed: {e}", c.id);
                } else {
                    println!(
                        "request {:3} done: {} tokens, ttft {:.1}ms, e2e {:.1}ms",
                        c.id,
                        c.tokens.len(),
                        c.ttft_secs * 1e3,
                        c.e2e_secs * 1e3
                    );
                }
            }
            CompletionWait::TimedOut => anyhow::bail!("timed out waiting for completions"),
            CompletionWait::Disconnected => anyhow::bail!(
                "coordinator scheduler died; the watchdog answered all pending \
                 requests with errors (health {:?})",
                coord.health()
            ),
        }
    }
    println!("\n{}", coord.metrics_summary());
    if coord.faults().enabled() {
        println!("fault injector: {}", coord.faults().summary());
    }
    println!("final health: {:?}", coord.health());
    coord.stop();
    Ok(())
}

/// `blast serve --replicas R` (R > 1): the same synthetic load, served
/// through the replicated fleet tier. Completions arrive exactly once no
/// matter which replicas crash, stall or get rolled mid-run.
#[allow(clippy::too_many_arguments)]
fn serve_fleet(
    args: &Args,
    engine: &Engine,
    batcher: BatcherConfig,
    faults: Faults,
    replicas: usize,
    n_requests: usize,
    max_new: usize,
    deadline_ms: Option<u64>,
    vocab: usize,
) -> Result<()> {
    use blast::coordinator::{Fleet, FleetConfig};
    let fcfg = FleetConfig {
        replicas,
        batcher,
        seed: args.get_usize("fleet-seed", 0) as u64,
        stall_ms: args.get_usize("stall-ms", 250) as u64,
        ..FleetConfig::default()
    };
    println!(
        "fleet: {replicas} replicas (seed {}, stall threshold {}ms)",
        fcfg.seed, fcfg.stall_ms
    );
    let mut fleet = Fleet::start_with_faults(engine, fcfg, faults);
    for i in 0..n_requests {
        let len = 8 + (i % 8);
        fleet.submit(Request {
            id: i as u64,
            prompt: (0..len).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect(),
            max_new,
            eos: None,
            deadline_ms,
        })?;
    }
    // optional mid-run zero-downtime roll of every replica
    if args.get_bool("rolling-restart") {
        fleet.rolling_restart()?;
        println!("rolling restart completed with requests in flight");
    }
    let mut done = 0;
    while done < n_requests {
        match fleet.next_completion(Duration::from_secs(120)) {
            CompletionWait::Ready(c) => {
                done += 1;
                if let Some(e) = c.error {
                    println!("request {} failed: {e}", c.id);
                } else {
                    println!(
                        "request {:3} done: {} tokens, ttft {:.1}ms, e2e {:.1}ms",
                        c.id,
                        c.tokens.len(),
                        c.ttft_secs * 1e3,
                        c.e2e_secs * 1e3
                    );
                }
            }
            CompletionWait::TimedOut => anyhow::bail!("timed out waiting for completions"),
            CompletionWait::Disconnected => {
                anyhow::bail!("fleet router exited before all completions arrived")
            }
        }
    }
    println!("\n{}", fleet.metrics_summary());
    println!("replica status: {:?}", fleet.statuses());
    fleet.stop();
    let undrained: usize = fleet.pools().iter().map(|p| p.pages_in_use()).sum();
    if undrained > 0 {
        anyhow::bail!("{undrained} KV pages still resident after fleet stop");
    }
    Ok(())
}

// Checkpoint loading is exercised by examples/finetune_glue.rs; keep the
// symbol referenced so the public API stays covered.
#[allow(dead_code)]
fn _load_for_api_coverage(path: &Path) -> Result<ParamStore> {
    ParamStore::load(path)
}
