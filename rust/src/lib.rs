//! # BLaST — Block Sparse Transformers
//!
//! A reproduction of *"BLaST: High Performance Inference and Pretraining
//! using BLock Sparse Transformers"* (Okanovic et al., 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1 (Pallas, build time)** — the paper's BSpMM + fused sparse-MLP
//!   kernels, in `python/compile/kernels/`, validated against pure-jnp
//!   oracles and lowered (interpret mode) into the AOT artifacts.
//! * **L2 (JAX, build time)** — the Transformer model family (GPT-2-style,
//!   Llama-style, ViT-style) with block-masked MLP weights; `train_step`,
//!   `eval_loss`, `prefill` and `decode_step` entry points exported as HLO
//!   text in `artifacts/`.
//! * **L3 (this crate, run time)** — the coordinator: the paper's blocked
//!   prune-and-grow algorithm ([`sparsify`]), the pretraining orchestrator
//!   ([`train`]), a batched inference server ([`coordinator`]), the PJRT
//!   runtime bridge ([`runtime`], behind the `pjrt` cargo feature; the
//!   default build substitutes a stub so the crate has zero external
//!   dependencies), and a native block-sparse kernel stack ([`kernels`],
//!   [`sparse`], [`tensor`], [`model`]) — one packed register-blocked
//!   micro-kernel under dense GEMM, BSpMM and the fused MLPs — that
//!   carries the wall-clock reproduction of the paper's Figures 4–6.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, and the `blast` binary is self-contained afterwards.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a module and bench target.

pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod model;
pub mod perf;
pub mod runtime;
pub mod sparse;
pub mod sparsify;
pub mod tensor;
pub mod testkit;
pub mod train;
pub mod util;
