//! TPU/MXU analytic estimates for the L1 Pallas BSpMM (DESIGN.md §8).
//!
//! Pallas kernels run here under `interpret=True` (CPU), whose wall-clock
//! says nothing about TPU behaviour. What *can* be reasoned about exactly
//! from the BlockSpec is the memory schedule: the VMEM working set per grid
//! step, the HBM→VMEM DMA volume (pruned blocks issue no DMA), and the MXU
//! occupancy bound implied by the tile shape vs the 128×128 systolic array.
//! These numbers drive the L1 structural optimization and are recorded in
//! EXPERIMENTS.md §Perf.

/// One (blk_m, b) kernel configuration at a given sparsity.
#[derive(Clone, Copy, Debug)]
pub struct KernelSpec {
    /// Rows of X per grid step (paper blk_M; our Pallas default 128).
    pub blk_m: usize,
    /// Sparse block edge (paper blk_N = b).
    pub block: usize,
    /// Problem shape Y(m,n) = X(m,k) W(k,n).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Block sparsity of W.
    pub sparsity: f64,
    /// Bytes per element (4 = f32, 2 = bf16).
    pub elem_bytes: usize,
}

pub const MXU_DIM: usize = 128;
/// Per-core VMEM on contemporary TPUs (v4/v5e ≈ 16 MiB); the budget the
/// BlockSpec must fit.
pub const VMEM_BYTES: usize = 16 << 20;

#[derive(Clone, Copy, Debug)]
pub struct Estimate {
    /// VMEM bytes resident per grid step (X tile + W block + acc tile).
    pub vmem_per_step: usize,
    /// Total HBM→VMEM DMA bytes for the whole kernel.
    pub dma_bytes: f64,
    /// Same for a dense kernel — the data-movement saving is the ratio.
    pub dma_bytes_dense: f64,
    /// Fraction of MXU lanes busy given the tile shape (≤ 1).
    pub mxu_utilization: f64,
    /// Upper bound on speedup over the dense kernel at this sparsity
    /// (compute-bound regime): 1 / (1 - s), derated by MXU occupancy.
    pub speedup_ceiling: f64,
    /// Does the working set fit VMEM?
    pub fits_vmem: bool,
}

pub fn estimate(s: &KernelSpec) -> Estimate {
    assert!(s.k % s.block == 0 && s.n % s.block == 0);
    let eb = s.elem_bytes;
    // per grid step: X tile (blk_m × b), W block (b × b), acc (blk_m × b)
    let vmem = eb * (s.blk_m * s.block + s.block * s.block) + 4 * s.blk_m * s.block;
    let kept = 1.0 - s.sparsity;
    let n_blocks = ((s.k / s.block) * (s.n / s.block)) as f64;
    let x_tiles = (s.m / s.blk_m.min(s.m)) as f64;
    // every kept W block DMA'd once per X row-tile pass; X tile re-DMA'd
    // once per kept block column entry
    let w_dma = kept * n_blocks * (s.block * s.block * eb) as f64 * x_tiles.max(1.0);
    let x_dma = kept * n_blocks * (s.blk_m * s.block * eb) as f64;
    let y_dma = (s.m * s.n * eb) as f64;
    let dma = w_dma + x_dma + y_dma;
    let dense = {
        let w = n_blocks * (s.block * s.block * eb) as f64 * x_tiles.max(1.0);
        let x = n_blocks * (s.blk_m * s.block * eb) as f64;
        w + x + y_dma
    };
    // MXU lanes: a b×b tile occupies (b/128)² of the array per issue; the
    // systolic array pipelines blk_m rows, so row occupancy is blk_m/128.
    let mxu = (s.block.min(MXU_DIM) as f64 / MXU_DIM as f64)
        * (s.blk_m.min(MXU_DIM) as f64 / MXU_DIM as f64);
    Estimate {
        vmem_per_step: vmem,
        dma_bytes: dma,
        dma_bytes_dense: dense,
        mxu_utilization: mxu,
        speedup_ceiling: mxu / kept.max(1e-9) / 1.0f64.max(mxu),
        fits_vmem: vmem <= VMEM_BYTES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(block: usize, sparsity: f64) -> KernelSpec {
        KernelSpec {
            blk_m: 128,
            block,
            m: 1024,
            k: 4096,
            n: 16384,
            sparsity,
            elem_bytes: 2,
        }
    }

    #[test]
    fn paper_blocks_fit_vmem() {
        for b in [32, 64, 128] {
            let e = estimate(&spec(b, 0.9));
            assert!(e.fits_vmem, "b={b} vmem={}", e.vmem_per_step);
        }
    }

    #[test]
    fn mxu_utilization_favors_128() {
        let u32_ = estimate(&spec(32, 0.9)).mxu_utilization;
        let u128 = estimate(&spec(128, 0.9)).mxu_utilization;
        assert!(u128 > u32_, "{u128} vs {u32_}");
        assert!((u128 - 1.0).abs() < 1e-9, "128×128 fills the MXU");
    }

    #[test]
    fn dma_savings_track_sparsity() {
        let e = estimate(&spec(128, 0.95));
        let saving = e.dma_bytes_dense / e.dma_bytes;
        // output writes are irreducible, so saving < 20× but well > 5×
        assert!(saving > 5.0 && saving < 20.0, "saving {saving}");
    }

    #[test]
    fn speedup_ceiling_at_95_is_about_20x() {
        let e = estimate(&spec(128, 0.95));
        assert!(
            (15.0..=21.0).contains(&e.speedup_ceiling),
            "ceiling {} — paper reports up to 16.7×",
            e.speedup_ceiling
        );
    }
}
