//! FLOP accounting under a sparsity schedule (Fig. 9).
//!
//! The ViT experiment plots accuracy against cumulative PFLOP: as the
//! schedule prunes the MLP blocks, each epoch costs fewer FLOPs. Attention
//! and embedding FLOPs are unaffected by BLaST and counted dense.

use crate::model::config::NativeConfig;
use crate::model::config::ModelKind;
use crate::sparsify::SparsitySchedule;

/// Dense forward FLOPs per token for one config (matmuls only — the
/// elementwise ops are < 1% and the paper's counters ignore them too).
pub fn dense_fwd_flops_per_token(cfg: &NativeConfig, seq: usize) -> f64 {
    let e = cfg.emb as f64;
    let f = cfg.ffn as f64;
    let attn_proj = 4.0 * 2.0 * e * e;
    let attn_scores = 2.0 * 2.0 * seq as f64 * e; // QK^T + AV per token
    let mlp_mats = match cfg.kind {
        ModelKind::Llama => 3.0,
        _ => 2.0,
    };
    let mlp = mlp_mats * 2.0 * e * f;
    let head = 2.0 * e * cfg.vocab as f64;
    cfg.layers as f64 * (attn_proj + attn_scores + mlp) + head
}

/// Forward FLOPs per token at MLP sparsity `s`.
pub fn sparse_fwd_flops_per_token(cfg: &NativeConfig, seq: usize, s: f64) -> f64 {
    let e = cfg.emb as f64;
    let f = cfg.ffn as f64;
    let mlp_mats = match cfg.kind {
        ModelKind::Llama => 3.0,
        _ => 2.0,
    };
    let mlp_dense = cfg.layers as f64 * mlp_mats * 2.0 * e * f;
    dense_fwd_flops_per_token(cfg, seq) - s * mlp_dense
}

/// Training FLOPs per token (fwd + bwd ≈ 3× fwd for matmul-dominated nets).
pub fn train_flops_per_token(cfg: &NativeConfig, seq: usize, s: f64) -> f64 {
    3.0 * sparse_fwd_flops_per_token(cfg, seq, s)
}

/// Cumulative training FLOPs over `iters` iterations of `tokens_per_iter`
/// under the schedule (the x-axis of Fig. 9).
pub fn cumulative_train_flops(
    cfg: &NativeConfig,
    seq: usize,
    tokens_per_iter: f64,
    schedule: &SparsitySchedule,
    iters: usize,
) -> f64 {
    (0..iters)
        .map(|i| tokens_per_iter * train_flops_per_token(cfg, seq, schedule.sparsity_at(i)))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NativeConfig {
        NativeConfig {
            name: "t".into(),
            kind: ModelKind::Gpt2,
            vocab: 1000,
            emb: 256,
            ffn: 1024,
            layers: 4,
            heads: 4,
            max_seq: 128,
            block: 32,
        }
    }

    #[test]
    fn sparsity_reduces_flops() {
        let c = cfg();
        let dense = sparse_fwd_flops_per_token(&c, 128, 0.0);
        let sparse = sparse_fwd_flops_per_token(&c, 128, 0.9);
        assert!((dense - dense_fwd_flops_per_token(&c, 128)).abs() < 1.0);
        assert!(sparse < dense);
        // MLP share of this config ≈ 2*2*e*f*L / total; 90% of it saved
        let mlp = 4.0 * 2.0 * 2.0 * 256.0 * 1024.0;
        assert!((dense - sparse - 0.9 * mlp).abs() < 1.0);
    }

    #[test]
    fn cumulative_flops_below_dense_schedule() {
        let c = cfg();
        let sched = SparsitySchedule::new(0.0, 0.9, 100, 0);
        let sparse = cumulative_train_flops(&c, 128, 1024.0, &sched, 100);
        let dense_sched = SparsitySchedule::new(0.0, 0.0, 100, 0);
        let dense = cumulative_train_flops(&c, 128, 1024.0, &dense_sched, 100);
        assert!(sparse < dense);
        assert!(sparse > 0.5 * dense, "cubic ramp keeps early iters dense-ish");
    }
}
