//! Analytic performance & cost models.
//!
//! * [`memory`] — inference memory footprint and GPU-count model (Fig. 7,
//!   and the 2.9×-fewer-GPUs headline of Fig. 1).
//! * [`flops`] — training/inference FLOP accounting under a sparsity
//!   schedule (Fig. 9's accuracy-per-PFLOP axis).
//! * [`roofline`] — TPU/MXU estimates for the L1 Pallas kernel (DESIGN.md
//!   §8): VMEM working set, DMA traffic, MXU utilization bound, and the
//!   implied speedup ceiling `1/(1-s)` the CPU kernels are checked against.

pub mod flops;
pub mod memory;
pub mod roofline;
