//! Inference memory footprint model (paper §5.2.1, Fig. 7).
//!
//! The paper counts the GH200s (96 GB HBM each) needed to hold FP32
//! weights. BLaST prunes only the MLP matrices, so:
//!
//! ```text
//! bytes(s) = 4 · [ non_mlp_params + (1 - s) · mlp_params ] + index(s)
//! gpus(s)  = ceil(bytes(s) / 96 GB)
//! ```
//!
//! `index(s)` is the BCSC bookkeeping (block row indices + column
//! pointers), which is negligible for the paper's block sizes but modeled
//! anyway for honesty at b = 1.

use crate::model::config::PaperGeometry;

pub const GH200_BYTES: f64 = 96e9;
pub const FP32: f64 = 4.0;

/// Weight bytes for a geometry at MLP sparsity `s` with block size `b`.
pub fn weight_bytes(g: &PaperGeometry, sparsity: f64, block: usize) -> f64 {
    assert!((0.0..=1.0).contains(&sparsity));
    let mlp = g.mlp_params() as f64;
    let non_mlp = (g.total_params() - mlp).max(0.0);
    let kept = (1.0 - sparsity) * mlp;
    // BCSC index: one i32 block-row id per kept block + col_ptr array
    let kept_blocks = kept / (block * block) as f64;
    let mats = if g.swiglu { 3.0 } else { 2.0 };
    let col_ptrs = g.layers as f64 * mats * (g.ffn.max(g.emb) / block + 1) as f64;
    FP32 * (non_mlp + kept) + 4.0 * (kept_blocks + col_ptrs)
}

/// GH200 GPUs required to hold the weights.
pub fn gpus_required(g: &PaperGeometry, sparsity: f64, block: usize) -> usize {
    (weight_bytes(g, sparsity, block) / GH200_BYTES).ceil().max(1.0) as usize
}

/// Memory reduction factor dense → sparse (the paper's "3.12×").
pub fn reduction_factor(g: &PaperGeometry, sparsity: f64, block: usize) -> f64 {
    weight_bytes(g, 0.0, block) / weight_bytes(g, sparsity, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::paper_geometry;

    #[test]
    fn dense_405b_needs_about_17_gpus() {
        let g = paper_geometry("Llama-3.1-405B");
        // 405e9 * 4B = 1.62 TB → 17 × 96 GB
        assert_eq!(gpus_required(&g, 0.0, 128), 17);
    }

    #[test]
    fn sparsity_cuts_gpus_about_3x_at_405b() {
        let g = paper_geometry("Llama-3.1-405B");
        let dense = gpus_required(&g, 0.0, 128);
        // The paper's 2.9× GPU-count headline corresponds to its 80%
        // pretraining sparsity point; our pure-weight-bytes model lands at
        // 2.8–3.0× there (17 → 6 GPUs).
        let sparse80 = gpus_required(&g, 0.80, 128);
        let ratio80 = dense as f64 / sparse80 as f64;
        assert!(
            (2.5..=3.2).contains(&ratio80),
            "expected ~2.9x at 80%, got {ratio80} ({dense} → {sparse80})"
        );
        // at 95% the pure-weight model exceeds the paper's figure (the
        // paper's footprint includes unsparsified runtime state)
        let sparse95 = gpus_required(&g, 0.95, 128);
        assert!(dense as f64 / sparse95 as f64 >= 2.9);
    }

    #[test]
    fn reduction_factor_matches_paper_band() {
        let g = paper_geometry("Llama-3.1-405B");
        // paper: "up to 3.12× inference memory usage reduction"; counting
        // weight bytes alone we must meet or exceed that at 95% sparsity
        let r = reduction_factor(&g, 0.95, 128);
        assert!(r >= 3.12, "reduction {r} below the paper's headline");
        assert!(r <= 6.0, "reduction {r} implausibly high");
        // and the ~84% point reproduces the headline number closely
        let r84 = reduction_factor(&g, 0.84, 128);
        assert!((2.9..=3.4).contains(&r84), "reduction@84% {r84}");
    }

    #[test]
    fn monotone_in_sparsity() {
        let g = paper_geometry("Llama-3.1-8B");
        let mut prev = f64::INFINITY;
        for s in [0.0, 0.5, 0.7, 0.9, 0.95] {
            let b = weight_bytes(&g, s, 128);
            assert!(b < prev);
            prev = b;
        }
    }

    #[test]
    fn tiny_blocks_pay_index_overhead() {
        let g = paper_geometry("Llama-3.2-1B");
        assert!(weight_bytes(&g, 0.9, 1) > weight_bytes(&g, 0.9, 128));
    }
}
