//! Parameter store: named dense tensors + binary checkpoint I/O.
//!
//! Checkpoints are the bridge between pipeline stages (pretrain → finetune
//! → serve): a tiny self-describing binary format (`BLST1` magic, JSON
//! header, raw little-endian f32 payload) so no external serialization
//! crate is needed.
//!
//! # Crash safety (v2 format)
//!
//! A checkpoint is often the *only* copy of a long training run, so writes
//! are atomic and reads are verified:
//!
//! * **Atomic replace** — the file is written to a `.tmp` sibling, fsynced,
//!   then renamed over the destination (plus a best-effort parent-directory
//!   fsync). A crash mid-save leaves the previous checkpoint untouched.
//! * **Per-tensor CRC32** — the v2 header is a JSON object
//!   `{"version": 2, "meta": {...}, "tensors": [{name, shape, crc}, ...]}`;
//!   every tensor's payload CRC is verified on load, so a torn or
//!   bit-flipped file is rejected instead of silently corrupting a run.
//!   Legacy v1 headers (a bare JSON array, no checksums) still load.
//! * **`meta`** — an arbitrary JSON object for callers
//!   ([`crate::train::Trainer`] stores optimizer step, iteration, masks and
//!   hyper-parameters there so a killed run resumes bit-identically).
//!
//! The `ckpt_torn_write` fault site simulates a crash mid-payload: the
//! `.tmp` file is abandoned half-written and the save returns an error —
//! the destination is never touched, which is exactly the protocol's
//! guarantee. The Python transliteration (`python/tests/ckpt_format_check.py`)
//! pins the byte layout and the CRC against `zlib.crc32`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ConfigInfo;
use crate::tensor::Tensor;
use crate::util::crc::crc32;
use crate::util::faults::{FaultSite, Faults};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A tensor's payload as raw little-endian bytes (f32, native LE layout).
fn tensor_bytes(t: &Tensor) -> &[u8] {
    let data = t.data();
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

/// Named parameter collection (insertion order = manifest ABI order).
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    order: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> ParamStore {
        ParamStore::default()
    }

    /// Initialize from a manifest config, mirroring the L2 `init_params`
    /// scheme (0.02 normals, scaled residual projections, unit norms).
    pub fn init(cfg: &ConfigInfo, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let resid_scale = 0.02 / (2.0 * cfg.layers as f32).sqrt();
        for (name, shape) in &cfg.params {
            let n: usize = shape.iter().product();
            let t = if name.ends_with("ln1")
                || name.ends_with("ln2")
                || name.ends_with("final_norm")
            {
                Tensor::full(shape, 1.0)
            } else if name == "cls_token" {
                Tensor::zeros(shape)
            } else {
                let scale = if name.ends_with("attn.wo") || name.ends_with("mlp.w3") {
                    resid_scale
                } else {
                    0.02
                };
                Tensor::new(shape, rng.normal_vec(n, scale))
            };
            store.insert(name.clone(), t);
        }
        store
    }

    /// Initialize weights for a [`crate::model::NativeConfig`] (the native
    /// engine's LM layout; used by examples/benches that run without AOT
    /// artifacts).
    pub fn init_native(cfg: &crate::model::config::NativeConfig, seed: u64) -> ParamStore {
        use crate::model::config::ModelKind;
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        let resid = 0.02 / (2.0 * cfg.layers as f32).sqrt();
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.02, &mut rng));
        if cfg.kind == ModelKind::Gpt2 {
            s.insert("pos_emb".into(), Tensor::randn(&[cfg.max_seq, e], 0.02, &mut rng));
        }
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.02, &mut rng));
            }
            s.insert(p("attn.wo"), Tensor::randn(&[e, e], resid, &mut rng));
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                let scale = if n.ends_with("w3") { resid } else { 0.02 };
                s.insert(p(n), Tensor::randn(&[r, c], scale, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.02, &mut rng));
        s
    }

    pub fn insert(&mut self, name: String, t: Tensor) {
        if !self.map.contains_key(&name) {
            self.order.push(name.clone());
        }
        self.map.insert(name, t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn req(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn total_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Values in ABI order (for flat positional calls).
    pub fn in_order(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.order.iter().map(move |n| (n, &self.map[n]))
    }

    // ---- checkpoint I/O ---------------------------------------------------

    /// Atomic, checksummed checkpoint write (no caller metadata).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_with_meta(path, &Json::obj(vec![]), &Faults::disabled())
    }

    /// Atomic, checksummed checkpoint write with a caller-supplied JSON
    /// `meta` object embedded in the header (v2 format). The bytes go to a
    /// `.tmp` sibling first (fsynced), then rename over `path` — a crash
    /// (or an injected `ckpt_torn_write` fault) mid-write leaves any
    /// previous checkpoint at `path` untouched and returns an error.
    pub fn save_with_meta(&self, path: &Path, meta: &Json, faults: &Faults) -> Result<()> {
        let tensors = Json::arr(self.order.iter().map(|n| {
            let t = &self.map[n];
            Json::obj(vec![
                ("name", Json::str(n)),
                (
                    "shape",
                    Json::arr(t.shape().iter().map(|&d| Json::num(d as f64))),
                ),
                ("crc", Json::num(crc32(tensor_bytes(t)) as f64)),
            ])
        }));
        let header = Json::obj(vec![
            ("version", Json::num(2.0)),
            ("meta", meta.clone()),
            ("tensors", tensors),
        ])
        .dump();
        let file_name = path
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("checkpoint");
        let tmp = path.with_file_name(format!("{file_name}.tmp"));
        let torn = faults.fire(FaultSite::CkptTornWrite);
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating checkpoint {tmp:?}"))?;
            f.write_all(b"BLST1")?;
            f.write_all(&(header.len() as u64).to_le_bytes())?;
            f.write_all(header.as_bytes())?;
            if torn {
                // simulate the crash: half of the first tensor reaches the
                // disk, then the writer dies — no rename, no cleanup, the
                // destination keeps its previous (valid) contents
                if let Some(n) = self.order.first() {
                    let b = tensor_bytes(&self.map[n]);
                    f.write_all(&b[..b.len() / 2])?;
                }
                f.sync_all().ok();
            } else {
                for n in &self.order {
                    f.write_all(tensor_bytes(&self.map[n]))?;
                }
                f.sync_all()
                    .with_context(|| format!("fsyncing checkpoint {tmp:?}"))?;
            }
        }
        if torn {
            bail!("injected ckpt_torn_write: save to {path:?} died mid-payload (tmp file abandoned)");
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} into place"))?;
        // best-effort parent-directory fsync so the rename itself survives
        // a power cut (not all filesystems allow dir fsync — ignore errors)
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                d.sync_all().ok();
            }
        }
        Ok(())
    }

    /// Load a checkpoint, discarding the header metadata.
    pub fn load(path: &Path) -> Result<ParamStore> {
        Ok(ParamStore::load_with_meta(path)?.0)
    }

    /// Load a checkpoint and its header `meta` object. v2 headers verify
    /// every tensor's CRC32 — a truncated or bit-flipped file is rejected
    /// with an error naming the damaged tensor. Legacy v1 headers (bare
    /// JSON array, written before checksums existed) load with an empty
    /// meta and no verification.
    pub fn load_with_meta(path: &Path) -> Result<(ParamStore, Json)> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 5];
        f.read_exact(&mut magic)
            .with_context(|| format!("reading magic of {path:?}"))?;
        if &magic != b"BLST1" {
            bail!("{path:?} is not a BLST1 checkpoint");
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        if hlen > (1 << 30) {
            bail!("{path:?}: implausible header length {hlen} (corrupt checkpoint)");
        }
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)
            .with_context(|| format!("reading header of {path:?} (truncated?)"))?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let (meta, items) = if header.as_arr().is_some() {
            // legacy v1: the header IS the tensor list; no meta, no CRCs
            (Json::obj(vec![]), header.as_arr().unwrap())
        } else {
            let version = header.usize_or("version", 0);
            if version != 2 {
                bail!("{path:?}: unsupported checkpoint version {version}");
            }
            let tensors = header
                .get("tensors")
                .and_then(|t| t.as_arr())
                .context("v2 header missing tensors array")?;
            let meta = header.get("meta").cloned().unwrap_or_else(|| Json::obj(vec![]));
            (meta, tensors)
        };
        let mut store = ParamStore::new();
        for item in items {
            let name = item.str_or("name", "");
            let shape: Vec<usize> = item
                .req("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect();
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes).with_context(|| {
                format!("reading tensor {name:?} of {path:?} (torn write / truncated?)")
            })?;
            if let Some(want) = item.get("crc").and_then(|c| c.as_usize()) {
                let got = crc32(&bytes) as usize;
                if got != want {
                    bail!(
                        "{path:?}: CRC mismatch for tensor {name:?} \
                         (stored {want:#010x}, computed {got:#010x}) — torn or corrupt checkpoint"
                    );
                }
            }
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            store.insert(name, Tensor::new(&shape, data));
        }
        Ok((store, meta))
    }

    /// Cheap structural validity check: magic, parseable header, and a
    /// file exactly as long as the header's tensor shapes demand. Catches
    /// torn/truncated writes without reading (or CRC-checking) the
    /// payload — the retention sweep uses it to count only checkpoints
    /// that are actually restorable. Legacy v1 headers carry no shape
    /// list we can trust cheaply, so they only get the magic/header check.
    pub fn quick_verify(path: &Path) -> Result<()> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 5];
        f.read_exact(&mut magic)
            .with_context(|| format!("reading magic of {path:?}"))?;
        if &magic != b"BLST1" {
            bail!("{path:?} is not a BLST1 checkpoint");
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb);
        if hlen > (1 << 30) {
            bail!("{path:?}: implausible header length {hlen} (corrupt checkpoint)");
        }
        let mut hbuf = vec![0u8; hlen as usize];
        f.read_exact(&mut hbuf)
            .with_context(|| format!("reading header of {path:?} (truncated?)"))?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let items = match header.as_arr() {
            Some(_) => return Ok(()), // legacy v1: nothing cheap to verify
            None => header
                .get("tensors")
                .and_then(|t| t.as_arr())
                .context("v2 header missing tensors array")?,
        };
        let mut payload: u64 = 0;
        for item in items {
            let n: usize = item
                .req("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .product();
            payload += 4 * n as u64;
        }
        let want = 5 + 8 + hlen + payload;
        let got = f.metadata()?.len();
        if got != want {
            bail!(
                "{path:?}: {got} bytes on disk, header demands {want} — torn or \
                 truncated checkpoint"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_config() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "gpt2".into(),
            vocab: 8,
            emb: 4,
            ffn: 8,
            layers: 1,
            heads: 1,
            head_dim: 4,
            seq: 4,
            batch: 1,
            block: 2,
            num_classes: 0,
            patch_dim: 0,
            lr: 1e-3,
            param_count: 0,
            paper_equiv: String::new(),
            params: vec![
                ("tok_emb".into(), vec![8, 4]),
                ("layer0.ln1".into(), vec![4]),
                ("layer0.mlp.w1".into(), vec![4, 8]),
                ("layer0.mlp.w3".into(), vec![8, 4]),
            ],
            masks: vec![
                ("layer0.mlp.w1".into(), vec![2, 4]),
                ("layer0.mlp.w3".into(), vec![4, 2]),
            ],
            mlp_weights: vec!["layer0.mlp.w1".into(), "layer0.mlp.w3".into()],
        }
    }

    #[test]
    fn init_shapes_and_norm_layers() {
        let s = ParamStore::init(&mini_config(), 0);
        assert_eq!(s.len(), 4);
        assert_eq!(s.req("tok_emb").shape(), &[8, 4]);
        // norm gains start at exactly 1
        assert!(s.req("layer0.ln1").data().iter().all(|&x| x == 1.0));
        // w3 has the scaled-down residual init
        let w3_absmax = s.req("layer0.mlp.w3").data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        assert!(w3_absmax < 0.1);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ParamStore::init(&mini_config(), 7);
        let b = ParamStore::init(&mini_config(), 7);
        assert!(a.req("tok_emb").allclose(b.req("tok_emb"), 0.0));
        let c = ParamStore::init(&mini_config(), 8);
        assert!(!a.req("tok_emb").allclose(c.req("tok_emb"), 0.0));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let s = ParamStore::init(&mini_config(), 3);
        let dir = std::env::temp_dir().join("blast_test_ckpt.bin");
        s.save(&dir).unwrap();
        let back = ParamStore::load(&dir).unwrap();
        assert_eq!(back.names(), s.names());
        for (n, t) in s.in_order() {
            assert!(back.req(n).allclose(t, 0.0), "mismatch in {n}");
        }
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let p = std::env::temp_dir().join("blast_test_garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(ParamStore::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn meta_roundtrips_through_v2_header() {
        let s = ParamStore::init(&mini_config(), 5);
        let p = std::env::temp_dir().join("blast_test_meta.blst");
        let meta = Json::obj(vec![
            ("iter", Json::num(42.0)),
            ("config", Json::str("micro")),
        ]);
        s.save_with_meta(&p, &meta, &Faults::disabled()).unwrap();
        let (back, m) = ParamStore::load_with_meta(&p).unwrap();
        assert_eq!(back.names(), s.names());
        assert_eq!(m.usize_or("iter", 0), 42);
        assert_eq!(m.str_or("config", ""), "micro");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_checkpoint_is_rejected() {
        let s = ParamStore::init(&mini_config(), 6);
        let p = std::env::temp_dir().join("blast_test_trunc.blst");
        s.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 7]).unwrap();
        let err = ParamStore::load(&p).unwrap_err().to_string();
        assert!(err.contains("torn") || err.contains("truncated"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bit_flip_in_payload_fails_crc() {
        let s = ParamStore::init(&mini_config(), 7);
        let p = std::env::temp_dir().join("blast_test_flip.blst");
        s.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 2; // inside the final tensor's payload
        bytes[last] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = ParamStore::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn torn_write_fault_leaves_previous_checkpoint_intact() {
        let good = ParamStore::init(&mini_config(), 8);
        let p = std::env::temp_dir().join("blast_test_torn.blst");
        good.save(&p).unwrap();
        // second save dies mid-payload (injected) — must error out and
        // must NOT disturb the existing file
        let newer = ParamStore::init(&mini_config(), 9);
        let faults = Faults::parse("ckpt_torn_write:1:1").unwrap();
        let err = newer.save_with_meta(&p, &Json::obj(vec![]), &faults).unwrap_err();
        assert!(err.to_string().contains("ckpt_torn_write"), "{err}");
        let back = ParamStore::load(&p).unwrap();
        assert!(back.req("tok_emb").allclose(good.req("tok_emb"), 0.0));
        // the abandoned tmp file is real crash debris: present and torn
        let tmp = p.with_file_name("blast_test_torn.blst.tmp");
        assert!(tmp.exists());
        assert!(ParamStore::load(&tmp).is_err(), "torn tmp must not load");
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn legacy_v1_array_header_still_loads() {
        // hand-build a v1 checkpoint: magic + bare-array header + payload
        let data: Vec<f32> = vec![1.0, -2.5, 3.25, 0.0];
        let header = Json::arr(vec![Json::obj(vec![
            ("name", Json::str("w")),
            (
                "shape",
                Json::arr(vec![Json::num(2.0), Json::num(2.0)]),
            ),
        ])])
        .dump();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"BLST1");
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        for v in &data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = std::env::temp_dir().join("blast_test_v1.blst");
        std::fs::write(&p, &bytes).unwrap();
        let (store, meta) = ParamStore::load_with_meta(&p).unwrap();
        assert_eq!(store.req("w").data(), &data[..]);
        assert!(meta.get("anything").is_none());
        std::fs::remove_file(&p).ok();
    }
}
