//! Parameter store: named dense tensors + binary checkpoint I/O.
//!
//! Checkpoints are the bridge between pipeline stages (pretrain → finetune
//! → serve): a tiny self-describing binary format (`BLST1` magic, JSON
//! header with names/shapes, raw little-endian f32 payload) so no external
//! serialization crate is needed.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::ConfigInfo;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Named parameter collection (insertion order = manifest ABI order).
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    order: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> ParamStore {
        ParamStore::default()
    }

    /// Initialize from a manifest config, mirroring the L2 `init_params`
    /// scheme (0.02 normals, scaled residual projections, unit norms).
    pub fn init(cfg: &ConfigInfo, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let resid_scale = 0.02 / (2.0 * cfg.layers as f32).sqrt();
        for (name, shape) in &cfg.params {
            let n: usize = shape.iter().product();
            let t = if name.ends_with("ln1")
                || name.ends_with("ln2")
                || name.ends_with("final_norm")
            {
                Tensor::full(shape, 1.0)
            } else if name == "cls_token" {
                Tensor::zeros(shape)
            } else {
                let scale = if name.ends_with("attn.wo") || name.ends_with("mlp.w3") {
                    resid_scale
                } else {
                    0.02
                };
                Tensor::new(shape, rng.normal_vec(n, scale))
            };
            store.insert(name.clone(), t);
        }
        store
    }

    /// Initialize weights for a [`crate::model::NativeConfig`] (the native
    /// engine's LM layout; used by examples/benches that run without AOT
    /// artifacts).
    pub fn init_native(cfg: &crate::model::config::NativeConfig, seed: u64) -> ParamStore {
        use crate::model::config::ModelKind;
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        let resid = 0.02 / (2.0 * cfg.layers as f32).sqrt();
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.02, &mut rng));
        if cfg.kind == ModelKind::Gpt2 {
            s.insert("pos_emb".into(), Tensor::randn(&[cfg.max_seq, e], 0.02, &mut rng));
        }
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.02, &mut rng));
            }
            s.insert(p("attn.wo"), Tensor::randn(&[e, e], resid, &mut rng));
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                let scale = if n.ends_with("w3") { resid } else { 0.02 };
                s.insert(p(n), Tensor::randn(&[r, c], scale, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.02, &mut rng));
        s
    }

    pub fn insert(&mut self, name: String, t: Tensor) {
        if !self.map.contains_key(&name) {
            self.order.push(name.clone());
        }
        self.map.insert(name, t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn req(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.map.get_mut(name)
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn total_elements(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Values in ABI order (for flat positional calls).
    pub fn in_order(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.order.iter().map(move |n| (n, &self.map[n]))
    }

    // ---- checkpoint I/O ---------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let header = Json::arr(self.order.iter().map(|n| {
            let t = &self.map[n];
            Json::obj(vec![
                ("name", Json::str(n)),
                (
                    "shape",
                    Json::arr(t.shape().iter().map(|&d| Json::num(d as f64))),
                ),
            ])
        }))
        .dump();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint {path:?}"))?;
        f.write_all(b"BLST1")?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for n in &self.order {
            let data = self.map[n].data();
            let bytes =
                unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {path:?}"))?;
        let mut magic = [0u8; 5];
        f.read_exact(&mut magic)?;
        if &magic != b"BLST1" {
            bail!("{path:?} is not a BLST1 checkpoint");
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let mut store = ParamStore::new();
        for item in header.as_arr().context("header array")? {
            let name = item.str_or("name", "");
            let shape: Vec<usize> = item
                .req("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().unwrap())
                .collect();
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            store.insert(name, Tensor::new(&shape, data));
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_config() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "gpt2".into(),
            vocab: 8,
            emb: 4,
            ffn: 8,
            layers: 1,
            heads: 1,
            head_dim: 4,
            seq: 4,
            batch: 1,
            block: 2,
            num_classes: 0,
            patch_dim: 0,
            lr: 1e-3,
            param_count: 0,
            paper_equiv: String::new(),
            params: vec![
                ("tok_emb".into(), vec![8, 4]),
                ("layer0.ln1".into(), vec![4]),
                ("layer0.mlp.w1".into(), vec![4, 8]),
                ("layer0.mlp.w3".into(), vec![8, 4]),
            ],
            masks: vec![
                ("layer0.mlp.w1".into(), vec![2, 4]),
                ("layer0.mlp.w3".into(), vec![4, 2]),
            ],
            mlp_weights: vec!["layer0.mlp.w1".into(), "layer0.mlp.w3".into()],
        }
    }

    #[test]
    fn init_shapes_and_norm_layers() {
        let s = ParamStore::init(&mini_config(), 0);
        assert_eq!(s.len(), 4);
        assert_eq!(s.req("tok_emb").shape(), &[8, 4]);
        // norm gains start at exactly 1
        assert!(s.req("layer0.ln1").data().iter().all(|&x| x == 1.0));
        // w3 has the scaled-down residual init
        let w3_absmax = s.req("layer0.mlp.w3").data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        assert!(w3_absmax < 0.1);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ParamStore::init(&mini_config(), 7);
        let b = ParamStore::init(&mini_config(), 7);
        assert!(a.req("tok_emb").allclose(b.req("tok_emb"), 0.0));
        let c = ParamStore::init(&mini_config(), 8);
        assert!(!a.req("tok_emb").allclose(c.req("tok_emb"), 0.0));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let s = ParamStore::init(&mini_config(), 3);
        let dir = std::env::temp_dir().join("blast_test_ckpt.bin");
        s.save(&dir).unwrap();
        let back = ParamStore::load(&dir).unwrap();
        assert_eq!(back.names(), s.names());
        for (n, t) in s.in_order() {
            assert!(back.req(n).allclose(t, 0.0), "mismatch in {n}");
        }
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let p = std::env::temp_dir().join("blast_test_garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(ParamStore::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
