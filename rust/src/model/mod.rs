//! Native model layer: geometry catalog, parameter store, and the
//! block-sparse inference engine.
//!
//! Two kinds of model geometry coexist (DESIGN.md §7):
//!
//! * **paper geometries** ([`config::paper_catalog`]) — the real
//!   Llama/GPT-2/ViT shapes, used by the analytic memory/FLOP models
//!   (Figs. 5, 7, 9);
//! * **scaled twins** (from the AOT manifest) — the shapes that actually
//!   run on this testbed, used by the engine, trainer and serving stack.
//!
//! The [`engine`] executes a decoder Transformer forward pass entirely on
//! the native kernel stack ([`crate::kernels`]), with the MLP in either
//! dense (GEMM) or block-sparse (BCSC/BSpMM) mode — the switch that
//! produces the paper's Fig. 6 end-to-end inference speedup.

pub mod config;
pub mod engine;
pub mod kv;
pub mod params;

pub use config::{
    lm_config_info, paper_catalog, sim_config, ModelKind, NativeConfig, PaperGeometry, SIM_CONFIGS,
};
pub use engine::{Engine, MlpMode};
pub use kv::{KvCache, KvOptions, KvPagePool, DEFAULT_KV_PAGE};
pub use params::ParamStore;
