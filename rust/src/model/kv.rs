//! Paged KV cache: fixed-size position pages from a shared pool, with
//! copy-on-write prefix sharing.
//!
//! The seed engine preallocated one flat `(heads × max_seq × hd)` buffer
//! per layer per session, so resident KV memory scaled with the
//! *configured* context length rather than the tokens a session actually
//! holds — directly against the paper's inference-memory-footprint
//! headline. This module replaces that with the vLLM-shaped layout:
//!
//! * a **page** covers [`KvGeom::page`] consecutive positions for *all*
//!   layers, both K and V, head-major within the page — one allocation
//!   per position span per session, and each `(layer, head, K|V)` stripe
//!   of a page is `page × hd` contiguous floats, exactly what the decode
//!   kernel walks;
//! * a [`KvPagePool`] shared by every session of an engine hands pages
//!   out on demand (`KvCache::ensure`) and recycles them when a session
//!   drops, with an optional hard capacity so the serving coordinator can
//!   admit sessions against real memory instead of hoping;
//! * [`KvCache::bytes`] reports **resident** bytes (pages actually held),
//!   not the `max_seq` bound.
//!
//! The layout is a pure indexing change: positions are written and read
//! in the same order as the flat cache, so engine outputs are
//! **bit-identical** across page sizes (a flat cache is just the
//! `page = max_seq` special case — asserted by the engine's
//! page-boundary tests).
//!
//! # Prefix sharing & copy-on-write
//!
//! At serving scale, thousands of sessions repeat the same system-prompt
//! / few-shot prefix, and the page is the natural dedup unit. When
//! [`KvOptions::prefix_cache`] is on (the default):
//!
//! * pages are refcounted (`Arc<KvPage>`) and the pool keeps a **prefix
//!   index**: a chained FNV-1a hash over page-aligned prompt-token runs
//!   maps each *full* prefix page to a [`Weak`] reference plus the exact
//!   tokens it was filled from (so a match is verified token-for-token —
//!   a hash collision can never alias wrong KV);
//! * a new session's prefill first walks the index
//!   ([`KvCache::attach_prefix`]) and maps every matching read-only page
//!   by bumping its refcount instead of recomputing it — the engine then
//!   resumes prefill from the first unshared position;
//! * any write to a shared (or index-registered) page goes through
//!   [`KvCache::make_private`]: **copy-on-write** — a fresh page is
//!   allocated, the stripes copied, and only this session's mapping is
//!   repointed. Decode always writes the private tail page, so steady
//!   decode never copies;
//! * the index holds only `Weak` refs, so it never pins a page: when the
//!   last mapping drops, the page's buffer returns to the free list and
//!   its index entry is purged ([`KvPage`]'s `Drop`). A drained pool is
//!   therefore exactly empty — physical *and* logical — which the chaos
//!   suite asserts.
//!
//! The pool tracks **logical** mappings (what sessions see) separately
//! from **physical** pages (what memory holds); their ratio is the
//! sharing multiplier that `ServeMetrics` surfaces as effective
//! capacity. With `prefix_cache` off every sharing path is compiled down
//! to a no-op branch and behavior is byte-for-byte the unshared pool.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, Weak};

use anyhow::{bail, Result};

/// Default positions per KV page (the block-granularity sweet spot the
/// BLaST/BLASST line of work uses for position blocking).
pub const DEFAULT_KV_PAGE: usize = 64;

/// Engine-facing KV layout knobs: positions per page, optional pool
/// capacity (pages), and prefix sharing. `blast serve --kv-page N
/// --kv-pool-pages M --prefix-cache false` maps straight onto this.
#[derive(Clone, Copy, Debug)]
pub struct KvOptions {
    /// Positions per page (clamped to the engine's `max_seq`).
    pub page: usize,
    /// Hard pool capacity in pages; `None` = unbounded.
    pub pool_pages: Option<usize>,
    /// Copy-on-write prefix sharing (default on). Off is byte-for-byte
    /// the unshared pool: no index, no refcount sharing, no CoW.
    pub prefix_cache: bool,
}

impl Default for KvOptions {
    fn default() -> Self {
        KvOptions {
            page: DEFAULT_KV_PAGE,
            pool_pages: None,
            prefix_cache: true,
        }
    }
}

/// Geometry of one cache: model shape + page size. Copied into every
/// [`KvCache`] so kernels can index pages without touching the pool lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    /// Transformer layers cached.
    pub layers: usize,
    /// Attention heads per layer.
    pub heads: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Positions per page.
    pub page: usize,
}

impl KvGeom {
    /// f32 values in one page: K and V, all layers, all heads, `page`
    /// positions.
    pub fn page_floats(&self) -> usize {
        2 * self.layers * self.heads * self.page * self.head_dim
    }

    /// Bytes of one page.
    pub fn page_bytes(&self) -> usize {
        self.page_floats() * 4
    }

    /// Pages needed to hold `positions` positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page)
    }

    /// Offset of the `(layer, K|V, head)` stripe inside a page
    /// (`which` = 0 for K, 1 for V). The stripe is `page × head_dim`
    /// contiguous floats, position-major.
    #[inline]
    fn stripe(&self, layer: usize, which: usize, head: usize) -> usize {
        ((layer * 2 + which) * self.heads + head) * self.page * self.head_dim
    }
}

/// 64-bit FNV-1a over a token's little-endian bytes, continuing `h` — the
/// step function of the pool's chained prefix hash. The chain value after
/// page `p`'s tokens is the index key of the `(p+1)·page`-token prefix,
/// so extending a prompt extends its key chain without rehashing.
#[inline]
fn fnv1a_token(mut h: u64, token: u32) -> u64 {
    const FNV_PRIME: u64 = 0x100_0000_01b3;
    for b in token.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a offset basis — the chain's starting value.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One refcounted KV page. Sessions map pages as `Arc<KvPage>`; the pool's
/// prefix index holds at most a [`Weak`] reference, so a page lives
/// exactly as long as some session maps it. Dropping the last mapping
/// returns the buffer to the pool's free list and purges the page's index
/// entry — refcounts structurally return to zero at drain.
pub struct KvPage {
    pool: Arc<KvPagePool>,
    /// Page payload; taken back by the pool on drop (`Box<[f32]>::default`
    /// is an empty box, so no unsafe is needed to move it out).
    data: Box<[f32]>,
    /// Prefix-index key, set once at registration (before the index takes
    /// its weak reference) so `Drop` can purge the entry.
    key: OnceLock<u64>,
    /// BLASST score-bound stamps: per `(layer, head)`, the max L2 norm of
    /// every K row ever written into this page (`layers × heads` slots,
    /// see [`KvCache::k_stamp`]). Lives on the page *struct*, not the
    /// recycled buffer, so a fresh allocation always starts from zero —
    /// a recycled buffer's stale stamps can never leak. Maintained only
    /// when the pool was built with stamping on; monotone under writes
    /// (an overwrite keeps the old max, which stays a valid upper
    /// bound), copied on CoW (the copy starts life with the donor's
    /// bound and invalidates upward from there on its own writes).
    kmax: Box<[f32]>,
}

impl Drop for KvPage {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.data);
        self.pool.release(buf, self.key.get().copied());
    }
}

/// A live prefix-index entry: the page holding positions
/// `[len − page, len)` of a prompt whose first `len` tokens are
/// `tokens[..len]`. Matches are verified against the stored tokens, never
/// trusted to the hash.
struct PrefixEntry {
    page: Weak<KvPage>,
    tokens: Arc<[u32]>,
    len: usize,
}

/// Cumulative + gauge sharing counters, snapshot under one pool lock so
/// the ratio is self-consistent.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prefix-index lookups (one per prefill with ≥ 1 full prompt page).
    pub lookups: u64,
    /// Lookups that mapped at least one shared page.
    pub hits: u64,
    /// Pages mapped from the index instead of being recomputed
    /// (cumulative).
    pub pages_shared: u64,
    /// Copy-on-write page copies performed (cumulative).
    pub cow_copies: u64,
    /// Current page mappings across all caches (logical pages).
    pub logical_pages: usize,
    /// Current physical pages held (== logical when nothing is shared).
    pub physical_pages: usize,
}

struct PoolInner {
    /// Recycled page buffers, ready for reuse without a fresh allocation.
    free: Vec<Box<[f32]>>,
    /// Physical pages currently held by live caches.
    in_use: usize,
    /// Peak of `in_use` since pool creation.
    high_water: usize,
    /// Page *mappings* across live caches: shared pages count once per
    /// mapping. `logical >= in_use`, equal when nothing is shared, and
    /// both must be zero once every cache drops.
    logical: usize,
    /// Prefix index: chained-hash key → weakly-held page + exact tokens.
    index: HashMap<u64, PrefixEntry>,
    lookups: u64,
    hits: u64,
    pages_shared: u64,
    cow_copies: u64,
}

/// Shared page allocator: every session's [`KvCache`] draws from (and
/// returns to) one pool, so resident KV memory is bounded and observable
/// process-wide. Cloneable via `Arc`; all methods take `&self`.
pub struct KvPagePool {
    geom: KvGeom,
    /// Hard capacity in pages; `None` = unbounded (tests, single-session
    /// tools). The serving coordinator uses the bound for admission.
    max_pages: Option<usize>,
    /// Prefix sharing armed at build time ([`KvOptions::prefix_cache`]).
    prefix_cache: bool,
    /// Maintain per-page K norm stamps on every write — armed by engines
    /// with a BLASST attention threshold; off costs nothing (one branch
    /// per `write_pos`).
    stamp_kmax: bool,
    inner: Mutex<PoolInner>,
}

impl KvPagePool {
    /// A pool for the given geometry; `max_pages = None` is unbounded,
    /// `prefix_cache` arms the sharing index. K norm stamping is off —
    /// use [`KvPagePool::new_with_stamping`] for threshold-armed engines.
    pub fn new(geom: KvGeom, max_pages: Option<usize>, prefix_cache: bool) -> Arc<KvPagePool> {
        Self::new_with_stamping(geom, max_pages, prefix_cache, false)
    }

    /// [`KvPagePool::new`] plus the `stamp_kmax` switch: when on, every
    /// [`KvCache::write_pos`] folds the written K row's L2 norm into the
    /// page's per-`(layer, head)` stamp so threshold-armed decode can
    /// skip whole pages by score bound.
    pub fn new_with_stamping(
        geom: KvGeom,
        max_pages: Option<usize>,
        prefix_cache: bool,
        stamp_kmax: bool,
    ) -> Arc<KvPagePool> {
        Arc::new(KvPagePool {
            geom,
            max_pages,
            prefix_cache,
            stamp_kmax,
            inner: Mutex::new(PoolInner {
                free: Vec::new(),
                in_use: 0,
                high_water: 0,
                logical: 0,
                index: HashMap::new(),
                lookups: 0,
                hits: 0,
                pages_shared: 0,
                cow_copies: 0,
            }),
        })
    }

    /// The pool lock. Page release runs from `Drop`, which may execute
    /// while a scheduler thread is unwinding — recover the data instead of
    /// compounding a poisoned mutex into an abort.
    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The geometry every page of this pool follows.
    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Hard capacity in pages (`None` = unbounded).
    pub fn capacity_pages(&self) -> Option<usize> {
        self.max_pages
    }

    /// Whether copy-on-write prefix sharing is armed.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix_cache
    }

    /// Whether per-page K norm stamping is armed.
    pub fn stamping_enabled(&self) -> bool {
        self.stamp_kmax
    }

    /// Physical pages currently held by live caches.
    pub fn pages_in_use(&self) -> usize {
        self.lock().in_use
    }

    /// Current page mappings across caches (each shared page counts once
    /// per session mapping it). Must drain to zero together with
    /// [`KvPagePool::pages_in_use`].
    pub fn logical_pages(&self) -> usize {
        self.lock().logical
    }

    /// Pages still allocatable right now (`None` = unbounded).
    pub fn available_pages(&self) -> Option<usize> {
        self.max_pages.map(|cap| cap.saturating_sub(self.lock().in_use))
    }

    /// Peak concurrent pages since pool creation — the number a capacity
    /// planner actually needs.
    pub fn high_water_pages(&self) -> usize {
        self.lock().high_water
    }

    /// Bytes resident in live caches right now (in-use pages only; the
    /// recycled free list is idle capacity, not session footprint).
    pub fn resident_bytes(&self) -> usize {
        self.pages_in_use() * self.geom.page_bytes()
    }

    /// One self-consistent snapshot of the sharing counters.
    pub fn prefix_stats(&self) -> PrefixStats {
        let inner = self.lock();
        PrefixStats {
            lookups: inner.lookups,
            hits: inner.hits,
            pages_shared: inner.pages_shared,
            cow_copies: inner.cow_copies,
            logical_pages: inner.logical,
            physical_pages: inner.in_use,
        }
    }

    /// Hand out one freshly mapped page, recycling a returned buffer when
    /// possible. Clean error — never a panic — when the pool is at
    /// capacity. Counts one physical page and one logical mapping.
    fn alloc(pool: &Arc<KvPagePool>) -> Result<Arc<KvPage>> {
        let data = {
            let mut inner = pool.lock();
            if let Some(cap) = pool.max_pages {
                if inner.in_use >= cap {
                    bail!(
                        "KV page pool exhausted: {} of {cap} pages in use",
                        inner.in_use
                    );
                }
            }
            inner.in_use += 1;
            inner.high_water = inner.high_water.max(inner.in_use);
            inner.logical += 1;
            // Recycled pages keep stale values: every read is bounded by
            // the owning cache's `len`, and every position is written
            // before `len` covers it, so stale floats are never observed.
            inner
                .free
                .pop()
                .unwrap_or_else(|| vec![0.0f32; pool.geom.page_floats()].into_boxed_slice())
        };
        // stamps are fresh (never recycled): a page starts with zero
        // bounds and only its own writes raise them
        let kmax = vec![0.0f32; pool.geom.layers * pool.geom.heads].into_boxed_slice();
        Ok(Arc::new(KvPage {
            pool: pool.clone(),
            data,
            key: OnceLock::new(),
            kmax,
        }))
    }

    /// Return a page buffer to the free list (called by [`KvPage`] on its
    /// final drop) and purge the page's index entry — unless the entry was
    /// already repointed at a newer live page.
    fn release(&self, buf: Box<[f32]>, key: Option<u64>) {
        let mut inner = self.lock();
        inner.in_use -= 1;
        inner.free.push(buf);
        if let Some(k) = key {
            if inner
                .index
                .get(&k)
                .is_some_and(|e| e.page.strong_count() == 0)
            {
                inner.index.remove(&k);
            }
        }
    }

    /// Drop `n` logical mappings (cache drop / CoW repoint). The physical
    /// side is handled by each page's own final drop.
    fn unmap_logical(&self, n: usize) {
        self.lock().logical -= n;
    }

    /// Map every index page matching a prefix of `tokens`, bumping
    /// refcounts — read path of prefix sharing. Returns the mapped pages
    /// in position order; stops at the first divergent or missing page.
    /// Only *full* pages are ever indexed, so the tail stays private.
    fn attach(&self, tokens: &[u32]) -> Vec<Arc<KvPage>> {
        let page = self.geom.page;
        if !self.prefix_cache || page == 0 || tokens.len() < page {
            return Vec::new();
        }
        let mut inner = self.lock();
        inner.lookups += 1;
        let mut out: Vec<Arc<KvPage>> = Vec::new();
        let mut h = FNV_OFFSET;
        for pi in 0..tokens.len() / page {
            for &t in &tokens[pi * page..(pi + 1) * page] {
                h = fnv1a_token(h, t);
            }
            let plen = (pi + 1) * page;
            let Some(e) = inner.index.get(&h) else { break };
            // exact verification: same prefix length and the same tokens —
            // the hash only narrows the candidate, it never decides
            if e.len != plen || e.tokens.len() < plen || e.tokens[..plen] != tokens[..plen] {
                break;
            }
            let Some(p) = e.page.upgrade() else { break };
            out.push(p);
        }
        if !out.is_empty() {
            inner.hits += 1;
            inner.pages_shared += out.len() as u64;
            inner.logical += out.len();
        }
        out
    }

    /// Read-only admission probe: how many pages a prefill of `tokens`
    /// would *not* need to allocate from the pool. This is the page count
    /// [`KvPagePool::attach`] would map, minus one when the prompt is
    /// fully covered by the index (the engine then rewrites the last
    /// position, which copy-on-writes one page). No refcounts move; if a
    /// donor session retires between probe and prefill the prefill simply
    /// allocates (or cleanly errors) like any other.
    pub fn probe_prefix(&self, tokens: &[u32]) -> usize {
        let page = self.geom.page;
        if !self.prefix_cache || page == 0 || tokens.len() < page {
            return 0;
        }
        let inner = self.lock();
        let mut m = 0usize;
        let mut h = FNV_OFFSET;
        for pi in 0..tokens.len() / page {
            for &t in &tokens[pi * page..(pi + 1) * page] {
                h = fnv1a_token(h, t);
            }
            let plen = (pi + 1) * page;
            let ok = inner.index.get(&h).is_some_and(|e| {
                e.len == plen
                    && e.tokens.len() >= plen
                    && e.tokens[..plen] == tokens[..plen]
                    && e.page.strong_count() > 0
            });
            if !ok {
                break;
            }
            m += 1;
        }
        if m > 0 && m * page == tokens.len() {
            m - 1
        } else {
            m
        }
    }

    /// Publish the full prompt pages of `tokens` into the prefix index
    /// (write path; called after a successful prefill). Live entries are
    /// never displaced — the first session to fill a prefix stays its
    /// donor until it retires; dead entries are repointed.
    fn register(&self, tokens: &[u32], pages: &[Arc<KvPage>]) {
        let page = self.geom.page;
        if !self.prefix_cache || page == 0 || tokens.len() < page {
            return;
        }
        let m = (tokens.len() / page).min(pages.len());
        let toks: Arc<[u32]> = tokens.into();
        let mut inner = self.lock();
        let mut h = FNV_OFFSET;
        for (pi, p) in pages.iter().enumerate().take(m) {
            for &t in &tokens[pi * page..(pi + 1) * page] {
                h = fnv1a_token(h, t);
            }
            if inner
                .index
                .get(&h)
                .is_some_and(|e| e.page.strong_count() > 0)
            {
                continue; // a live donor already publishes this prefix
            }
            // a page registers under exactly one key, set before the index
            // takes its weak ref so Drop can purge the entry
            match p.key.get() {
                None => {
                    let _ = p.key.set(h);
                }
                Some(&k) if k == h => {}
                Some(_) => continue,
            }
            inner.index.insert(
                h,
                PrefixEntry {
                    page: Arc::downgrade(p),
                    tokens: toks.clone(),
                    len: (pi + 1) * page,
                },
            );
        }
    }

    /// Record one copy-on-write page copy.
    fn note_cow(&self) {
        self.lock().cow_copies += 1;
    }
}

/// Per-session KV cache backed by pool pages, allocated on demand as the
/// sequence grows and returned to the pool on drop. With prefix sharing
/// on, leading pages may be shared mappings (see [`KvCache::attach_prefix`]);
/// writes to them go through [`KvCache::make_private`] first.
pub struct KvCache {
    pool: Arc<KvPagePool>,
    geom: KvGeom,
    pages: Vec<Arc<KvPage>>,
    /// Number of valid positions (same meaning as the seed flat cache).
    pub len: usize,
}

impl KvCache {
    /// An empty cache over `pool`; no pages are held until
    /// [`KvCache::ensure`] is called.
    pub fn new(pool: Arc<KvPagePool>) -> KvCache {
        let geom = pool.geom();
        KvCache {
            pool,
            geom,
            pages: Vec::new(),
            len: 0,
        }
    }

    /// Resident bytes of this cache — pages actually held, **not** the
    /// `max_seq` preallocation bound the seed cache reported. Shared
    /// mappings count here (they are this session's working set); the
    /// pool's physical residency is the deduplicated truth.
    pub fn bytes(&self) -> usize {
        self.pages.len() * self.geom.page_bytes()
    }

    /// Pages currently mapped (shared + private).
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Positions per page of this cache's layout.
    pub fn page_positions(&self) -> usize {
        self.geom.page
    }

    /// Map every prefix-index page matching a leading run of `tokens`
    /// (refcount bump, no compute, no copy). Returns how many pages were
    /// mapped; the engine resumes prefill after them. Only an empty cache
    /// attaches — a retried session re-prefills into pages it already
    /// owns, where remapping would alias someone else's positions.
    pub fn attach_prefix(&mut self, tokens: &[u32]) -> usize {
        if self.len != 0 || !self.pages.is_empty() {
            return 0;
        }
        let got = self.pool.attach(tokens);
        let n = got.len();
        self.pages.extend(got);
        n
    }

    /// Publish this cache's full prompt pages into the pool's prefix
    /// index so later sessions can map them (no-op when sharing is off).
    /// Call after a successful prefill of `tokens`.
    pub fn register_prefix(&self, tokens: &[u32]) {
        self.pool.register(tokens, &self.pages);
    }

    /// Grow to cover `positions` positions, allocating pages from the
    /// pool on demand. Clean error on pool exhaustion; the cache keeps
    /// the pages it already acquired (its `len` and contents are
    /// untouched either way).
    pub fn ensure(&mut self, positions: usize) -> Result<()> {
        let need = self.geom.pages_for(positions);
        while self.pages.len() < need {
            self.pages.push(KvPagePool::alloc(&self.pool)?);
        }
        Ok(())
    }

    /// Whether page `pi` is exclusively this cache's: no other session
    /// maps it and the prefix index holds no reference to it.
    pub fn page_is_private(&mut self, pi: usize) -> bool {
        Arc::get_mut(&mut self.pages[pi]).is_some()
    }

    /// Copy-on-write: make page `pi` exclusively writable. A page shared
    /// with another session — or published in the prefix index, whose weak
    /// ref must keep serving the *donor's* bits — is replaced by a fresh
    /// pool page carrying a copy of its stripes; only this cache's mapping
    /// is repointed. Already-private pages are a no-op. Clean error on
    /// pool exhaustion (the shared mapping stays usable).
    pub fn make_private(&mut self, pi: usize) -> Result<()> {
        if self.page_is_private(pi) {
            return Ok(());
        }
        let mut fresh = KvPagePool::alloc(&self.pool)?;
        {
            let f = Arc::get_mut(&mut fresh).expect("freshly allocated page is unshared");
            f.data.copy_from_slice(&self.pages[pi].data);
            // the copy carries the donor's KV bits, so it must carry the
            // donor's score bounds too — its own writes then invalidate
            // the stamp upward from here (the donor's stamp is untouched)
            f.kmax.copy_from_slice(&self.pages[pi].kmax);
        }
        self.pool.note_cow();
        // repoint: one logical mapping moves from the shared page to the
        // copy (alloc counted the copy, so drop this mapping's old count)
        let old = std::mem::replace(&mut self.pages[pi], fresh);
        self.pool.unmap_logical(1);
        drop(old);
        Ok(())
    }

    /// [`KvCache::ensure`] plus copy-on-write of the page covering the
    /// last position — the write-path growth call: after it, position
    /// `positions − 1` is writable without touching any shared page.
    /// (Pages past the first written one are freshly allocated, hence
    /// already private.)
    pub fn ensure_writable(&mut self, positions: usize) -> Result<()> {
        self.ensure(positions)?;
        if positions > 0 {
            self.make_private((positions - 1) / self.geom.page)?;
        }
        Ok(())
    }

    /// The `(page × hd)` K stripe of `(layer, head)` in page `pi`
    /// (position-major). Positions `pi*page ..` of the sequence.
    #[inline]
    pub fn k_head(&self, layer: usize, head: usize, pi: usize) -> &[f32] {
        let o = self.geom.stripe(layer, 0, head);
        &self.pages[pi].data[o..o + self.geom.page * self.geom.head_dim]
    }

    /// The `(page × hd)` V stripe of `(layer, head)` in page `pi`.
    #[inline]
    pub fn v_head(&self, layer: usize, head: usize, pi: usize) -> &[f32] {
        let o = self.geom.stripe(layer, 1, head);
        &self.pages[pi].data[o..o + self.geom.page * self.geom.head_dim]
    }

    /// The page's BLASST score-bound stamp for `(layer, head)`: an upper
    /// bound on the L2 norm of every K row positions of page `pi` hold
    /// for that `(layer, head)` — `q·k ≤ ‖q‖ · k_stamp` by
    /// Cauchy–Schwarz, which is what threshold-armed decode skips pages
    /// by. Zero until the first write (a page with no written K rows
    /// bounds every score at 0); only meaningful when the pool stamps
    /// ([`KvPagePool::stamping_enabled`]).
    #[inline]
    pub fn k_stamp(&self, layer: usize, head: usize, pi: usize) -> f32 {
        self.pages[pi].kmax[layer * self.geom.heads + head]
    }

    /// Write one position's K and V rows for `(layer, head)`. The page
    /// covering `pos` must already exist **and be private** — growth goes
    /// through [`KvCache::ensure_writable`] (or plain [`KvCache::ensure`]
    /// for pages that were never shared), which copy-on-writes first.
    ///
    /// # Panics
    /// If the covering page is still shared or index-registered: writing
    /// through it would corrupt other sessions' KV.
    #[inline]
    pub fn write_pos(&mut self, layer: usize, head: usize, pos: usize, k: &[f32], v: &[f32]) {
        let hd = self.geom.head_dim;
        debug_assert_eq!(k.len(), hd);
        debug_assert_eq!(v.len(), hd);
        let (pi, off) = (pos / self.geom.page, pos % self.geom.page);
        let ko = self.geom.stripe(layer, 0, head) + off * hd;
        let vo = self.geom.stripe(layer, 1, head) + off * hd;
        let page = Arc::get_mut(&mut self.pages[pi])
            .expect("KV write to a shared page (copy-on-write was skipped)");
        page.data[ko..ko + hd].copy_from_slice(k);
        page.data[vo..vo + hd].copy_from_slice(v);
        if self.pool.stamp_kmax {
            // fold the new K row's norm into the page's (layer, head)
            // bound; monotone max keeps the stamp a valid upper bound
            // even when a position is overwritten with a smaller key
            let norm = k.iter().map(|x| x * x).sum::<f32>().sqrt();
            let slot = &mut page.kmax[layer * self.geom.heads + head];
            *slot = slot.max(norm);
        }
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        // logical mappings go first (one pool lock), then each page whose
        // last mapping this was returns its buffer via its own Drop
        self.pool.unmap_logical(self.pages.len());
        self.pages.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(page: usize) -> KvGeom {
        KvGeom {
            layers: 2,
            heads: 3,
            head_dim: 4,
            page,
        }
    }

    fn pool(page: usize, cap: Option<usize>) -> Arc<KvPagePool> {
        KvPagePool::new(geom(page), cap, true)
    }

    /// Fill positions `0..n` of `c` with a per-(layer, head, pos, dim)
    /// pattern offset by `salt`, and set `len`.
    fn fill(c: &mut KvCache, n: usize, salt: f32) {
        c.ensure(n).unwrap();
        for li in 0..2 {
            for hh in 0..3 {
                for pos in 0..n {
                    let base = (li * 1000 + hh * 100 + pos * 10) as f32 + salt;
                    let k: Vec<f32> = (0..4).map(|d| base + d as f32).collect();
                    let v: Vec<f32> = (0..4).map(|d| -(base + d as f32)).collect();
                    c.write_pos(li, hh, pos, &k, &v);
                }
            }
        }
        c.len = n;
    }

    #[test]
    fn geometry_math() {
        let g = geom(8);
        assert_eq!(g.page_floats(), 2 * 2 * 3 * 8 * 4);
        assert_eq!(g.page_bytes(), g.page_floats() * 4);
        assert_eq!(g.pages_for(0), 0);
        assert_eq!(g.pages_for(1), 1);
        assert_eq!(g.pages_for(8), 1);
        assert_eq!(g.pages_for(9), 2);
    }

    #[test]
    fn write_then_read_roundtrip_across_pages() {
        let pool = pool(2, None);
        let mut c = KvCache::new(pool);
        c.ensure(5).unwrap();
        assert_eq!(c.pages_held(), 3);
        fill(&mut c, 5, 0.0);
        for li in 0..2 {
            for hh in 0..3 {
                for pos in 0..5 {
                    let (pi, off) = (pos / 2, pos % 2);
                    let k = &c.k_head(li, hh, pi)[off * 4..off * 4 + 4];
                    let v = &c.v_head(li, hh, pi)[off * 4..off * 4 + 4];
                    let base = (li * 1000 + hh * 100 + pos * 10) as f32;
                    for d in 0..4 {
                        assert_eq!(k[d], base + d as f32, "K l{li} h{hh} p{pos} d{d}");
                        assert_eq!(v[d], -(base + d as f32), "V l{li} h{hh} p{pos} d{d}");
                    }
                }
            }
        }
    }

    #[test]
    fn pool_counts_and_high_water() {
        let pool = pool(4, Some(4));
        assert_eq!(pool.available_pages(), Some(4));
        let mut a = KvCache::new(pool.clone());
        a.ensure(8).unwrap(); // 2 pages
        let mut b = KvCache::new(pool.clone());
        b.ensure(4).unwrap(); // 1 page
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.logical_pages(), 3);
        assert_eq!(pool.available_pages(), Some(1));
        assert_eq!(pool.resident_bytes(), 3 * pool.geom().page_bytes());
        drop(a);
        assert_eq!(pool.pages_in_use(), 1);
        assert_eq!(pool.logical_pages(), 1);
        // high water sticks at the peak
        assert_eq!(pool.high_water_pages(), 3);
        // released pages are recycled, not lost
        let mut c2 = KvCache::new(pool.clone());
        c2.ensure(12).unwrap();
        assert_eq!(pool.pages_in_use(), 4);
        assert_eq!(pool.high_water_pages(), 4);
    }

    #[test]
    fn exhaustion_is_a_clean_error_and_keeps_acquired_pages() {
        let pool = pool(2, Some(2));
        let mut c = KvCache::new(pool.clone());
        let err = c.ensure(6).unwrap_err(); // needs 3 pages, cap 2
        assert!(err.to_string().contains("exhausted"), "{err}");
        // the two acquired pages stay with the cache (len untouched)
        assert_eq!(c.pages_held(), 2);
        assert_eq!(c.len, 0);
        // freeing makes the allocation succeed for others
        drop(c);
        let mut d = KvCache::new(pool.clone());
        d.ensure(4).unwrap();
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn bytes_report_resident_pages_only() {
        let pool = pool(8, None);
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.bytes(), 0);
        c.ensure(1).unwrap();
        assert_eq!(c.bytes(), pool.geom().page_bytes());
        c.ensure(9).unwrap();
        assert_eq!(c.bytes(), 2 * pool.geom().page_bytes());
        // ensure() never shrinks; bytes track pages held
        c.ensure(3).unwrap();
        assert_eq!(c.bytes(), 2 * pool.geom().page_bytes());
    }

    #[test]
    fn zero_capacity_pool_rejects_first_page() {
        let pool = pool(2, Some(0));
        let mut c = KvCache::new(pool);
        assert!(c.ensure(1).is_err());
        assert_eq!(c.pages_held(), 0);
    }

    #[test]
    fn hash_chain_extends_per_page() {
        // the chain value after p pages is a pure function of those
        // tokens: same prefix → same keys, one differing token → a
        // different key from that page on
        let chain = |toks: &[u32]| {
            let mut h = FNV_OFFSET;
            let mut keys = Vec::new();
            for (i, &t) in toks.iter().enumerate() {
                h = fnv1a_token(h, t);
                if (i + 1) % 4 == 0 {
                    keys.push(h);
                }
            }
            keys
        };
        let a = chain(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let b = chain(&[1, 2, 3, 4, 5, 6, 7, 9]);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0], "shared first page must share its key");
        assert_ne!(a[1], b[1], "divergent page must change its key");
    }

    #[test]
    fn attach_maps_matching_full_pages_only() {
        let pool = pool(4, None);
        let prompt: Vec<u32> = (0..10).collect(); // 2 full pages + tail 2
        let mut donor = KvCache::new(pool.clone());
        fill(&mut donor, 10, 0.0);
        donor.register_prefix(&prompt);
        assert_eq!(pool.pages_in_use(), 3);

        // exact prefix: both full pages map; the tail page never does
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.attach_prefix(&prompt), 2);
        assert_eq!(c.pages_held(), 2);
        // physically the same pages — pointer-equal stripes
        assert!(std::ptr::eq(c.k_head(0, 0, 0).as_ptr(), donor.k_head(0, 0, 0).as_ptr()));
        assert_eq!(pool.pages_in_use(), 3, "sharing allocates nothing");
        assert_eq!(pool.logical_pages(), 5);

        // divergence inside page 1 → only page 0 maps
        let mut div: Vec<u32> = prompt.clone();
        div[5] = 99;
        let mut d = KvCache::new(pool.clone());
        assert_eq!(d.attach_prefix(&div), 1);

        // shorter-than-a-page prompts never look up
        let mut e = KvCache::new(pool.clone());
        assert_eq!(e.attach_prefix(&[0, 1, 2]), 0);

        let stats = pool.prefix_stats();
        assert_eq!(stats.lookups, 2, "sub-page prompt must not count a lookup");
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.pages_shared, 3);
    }

    #[test]
    fn cow_copy_never_aliases_the_shared_page() {
        let pool = pool(4, None);
        let prompt: Vec<u32> = (0..8).collect();
        let mut donor = KvCache::new(pool.clone());
        fill(&mut donor, 8, 0.0);
        donor.register_prefix(&prompt);

        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.attach_prefix(&prompt), 2);
        assert!(!c.page_is_private(1), "attached pages are shared");
        let before: Vec<f32> = donor.k_head(1, 2, 1).to_vec();

        // CoW page 1, then write a canary into the copy
        c.make_private(1).unwrap();
        assert!(c.page_is_private(1));
        assert!(
            !std::ptr::eq(c.k_head(1, 2, 1).as_ptr(), donor.k_head(1, 2, 1).as_ptr()),
            "the copy must live in different memory"
        );
        c.write_pos(1, 2, 5, &[9e9; 4], &[-9e9; 4]);
        // re-read the original: bit-for-bit untouched
        assert_eq!(donor.k_head(1, 2, 1), &before[..], "canary leaked into the shared page");
        assert_eq!(c.k_head(1, 2, 1)[4..8], [9e9; 4]);
        // page 0 stays shared — CoW is per-page, not per-cache
        assert!(std::ptr::eq(c.k_head(0, 0, 0).as_ptr(), donor.k_head(0, 0, 0).as_ptr()));

        let stats = pool.prefix_stats();
        assert_eq!(stats.cow_copies, 1);
        // 2 donor + 2 attached mappings; the CoW swap is logical-neutral
        assert_eq!(stats.logical_pages, 4);
        // 2 donor pages + the copy
        assert_eq!(stats.physical_pages, 3);
    }

    #[test]
    fn registered_pages_cow_even_when_refcount_is_one() {
        // the index holds a weak ref serving the donor's bits to future
        // sessions; a write through a registered page must copy first even
        // if no other session currently maps it
        let pool = pool(4, None);
        let prompt: Vec<u32> = (0..4).collect();
        let mut c = KvCache::new(pool.clone());
        fill(&mut c, 4, 0.0);
        c.register_prefix(&prompt);
        assert!(!c.page_is_private(0), "registration pins writability");
        c.ensure_writable(4).unwrap();
        assert!(c.page_is_private(0));
        assert_eq!(pool.prefix_stats().cow_copies, 1);
        // the index entry still serves the original page's content until
        // its last mapping (the CoW drop above was the last) releases it —
        // here the original died, so the entry purged and a fresh prompt
        // recomputes
        let mut d = KvCache::new(pool.clone());
        assert_eq!(d.attach_prefix(&prompt), 0);
    }

    #[test]
    fn refcounts_and_mappings_drain_to_zero() {
        let pool = pool(4, Some(16));
        let prompt: Vec<u32> = (0..12).collect();
        {
            let mut donor = KvCache::new(pool.clone());
            fill(&mut donor, 12, 0.0);
            donor.register_prefix(&prompt);
            let mut sharers: Vec<KvCache> = Vec::new();
            for _ in 0..4 {
                let mut c = KvCache::new(pool.clone());
                assert_eq!(c.attach_prefix(&prompt), 3);
                sharers.push(c);
            }
            assert_eq!(pool.pages_in_use(), 3);
            assert_eq!(pool.logical_pages(), 3 + 4 * 3);
            // one sharer copy-on-writes, another drops early
            sharers[0].make_private(2).unwrap();
            sharers.pop();
            assert_eq!(pool.pages_in_use(), 4);
            assert_eq!(pool.logical_pages(), 3 + 3 * 3);
        }
        // every cache gone: physical, logical and the index all empty
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.logical_pages(), 0);
        assert_eq!(pool.lock().index.len(), 0, "dead index entries must purge");
        // buffers were recycled, and a fresh prompt finds no stale match
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.attach_prefix(&prompt), 0);
        c.ensure(4).unwrap();
    }

    #[test]
    fn probe_matches_attach_and_charges_the_cow_page() {
        let pool = pool(4, None);
        let prompt: Vec<u32> = (0..12).collect();
        let mut donor = KvCache::new(pool.clone());
        fill(&mut donor, 12, 0.0);
        donor.register_prefix(&prompt);

        // partial coverage: probe == pages attach would map
        let longer: Vec<u32> = (0..14).collect();
        assert_eq!(pool.probe_prefix(&longer), 3);
        // full coverage: the engine rewrites the last position → one CoW
        // allocation, so the probe discounts one page
        assert_eq!(pool.probe_prefix(&prompt), 2);
        // no coverage
        assert_eq!(pool.probe_prefix(&[7, 7, 7, 7, 7]), 0);
        // probing moves no refcounts and no stats
        let stats = pool.prefix_stats();
        assert_eq!((stats.lookups, stats.hits, stats.pages_shared), (0, 0, 0));
        assert_eq!(pool.logical_pages(), 3);
    }

    #[test]
    fn prefix_cache_off_is_the_unshared_pool() {
        let pool = KvPagePool::new(geom(4), None, false);
        let prompt: Vec<u32> = (0..8).collect();
        let mut donor = KvCache::new(pool.clone());
        fill(&mut donor, 8, 0.0);
        donor.register_prefix(&prompt);
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.attach_prefix(&prompt), 0);
        assert_eq!(pool.probe_prefix(&prompt), 0);
        assert_eq!(pool.prefix_stats(), PrefixStats {
            logical_pages: 2,
            physical_pages: 2,
            ..PrefixStats::default()
        });
        // writes stay in place — no CoW ever
        donor.ensure_writable(8).unwrap();
        assert_eq!(pool.prefix_stats().cow_copies, 0);
    }

    #[test]
    fn kmax_stamp_lifecycle_write_cow_recycle() {
        let pool = KvPagePool::new_with_stamping(geom(4), None, true, true);
        let prompt: Vec<u32> = (0..4).collect();
        let mut donor = KvCache::new(pool.clone());
        donor.ensure(4).unwrap();
        // two writes into (layer 1, head 2): stamp must hold the max norm
        donor.write_pos(1, 2, 0, &[3.0, 4.0, 0.0, 0.0], &[0.0; 4]); // ‖k‖ = 5
        donor.write_pos(1, 2, 1, &[1.0, 0.0, 0.0, 0.0], &[0.0; 4]); // ‖k‖ = 1
        assert_eq!(donor.k_stamp(1, 2, 0), 5.0);
        // untouched (layer, head) slots bound every score at zero
        assert_eq!(donor.k_stamp(0, 1, 0), 0.0);
        donor.len = 4;
        donor.register_prefix(&prompt);

        // CoW: the copy starts with the donor's stamp and raises it on
        // its own writes; the donor's stamp never moves
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.attach_prefix(&prompt), 1);
        assert_eq!(c.k_stamp(1, 2, 0), 5.0, "shared mapping sees the donor stamp");
        c.make_private(0).unwrap();
        assert_eq!(c.k_stamp(1, 2, 0), 5.0, "CoW copies the stamp");
        c.write_pos(1, 2, 2, &[0.0, 0.0, 6.0, 8.0], &[0.0; 4]); // ‖k‖ = 10
        assert_eq!(c.k_stamp(1, 2, 0), 10.0);
        assert_eq!(donor.k_stamp(1, 2, 0), 5.0, "donor stamp untouched by the copy");

        // overwriting with a smaller key keeps the old bound (monotone,
        // still a sound upper bound)
        c.write_pos(1, 2, 2, &[0.1, 0.0, 0.0, 0.0], &[0.0; 4]);
        assert_eq!(c.k_stamp(1, 2, 0), 10.0);

        // recycled buffers must not leak stale stamps: drop everything,
        // then a fresh page (reusing the freed buffer) starts at zero
        drop(donor);
        drop(c);
        let mut fresh = KvCache::new(pool.clone());
        fresh.ensure(4).unwrap();
        assert_eq!(fresh.k_stamp(1, 2, 0), 0.0, "fresh page must start unstamped");
    }

    #[test]
    fn stamping_off_is_free_and_zero() {
        let pool = pool(4, None); // stamping off
        assert!(!pool.stamping_enabled());
        let mut c = KvCache::new(pool);
        c.ensure(4).unwrap();
        c.write_pos(0, 0, 0, &[3.0, 4.0, 0.0, 0.0], &[0.0; 4]);
        assert_eq!(c.k_stamp(0, 0, 0), 0.0, "unarmed pools never stamp");
    }

    #[test]
    fn a_retried_nonempty_cache_never_attaches() {
        let pool = pool(4, None);
        let prompt: Vec<u32> = (0..8).collect();
        let mut donor = KvCache::new(pool.clone());
        fill(&mut donor, 8, 0.0);
        donor.register_prefix(&prompt);
        // a cache that already holds pages (failed prefill retry path)
        // must re-fill in place, not remap
        let mut c = KvCache::new(pool.clone());
        c.ensure(4).unwrap();
        assert_eq!(c.attach_prefix(&prompt), 0);
    }
}
