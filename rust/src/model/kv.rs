//! Paged KV cache: fixed-size position pages from a shared pool.
//!
//! The seed engine preallocated one flat `(heads × max_seq × hd)` buffer
//! per layer per session, so resident KV memory scaled with the
//! *configured* context length rather than the tokens a session actually
//! holds — directly against the paper's inference-memory-footprint
//! headline. This module replaces that with the vLLM-shaped layout:
//!
//! * a **page** covers [`KvGeom::page`] consecutive positions for *all*
//!   layers, both K and V, head-major within the page — one allocation
//!   per position span per session, and each `(layer, head, K|V)` stripe
//!   of a page is `page × hd` contiguous floats, exactly what the decode
//!   kernel walks;
//! * a [`KvPagePool`] shared by every session of an engine hands pages
//!   out on demand (`KvCache::ensure`) and recycles them when a session
//!   drops, with an optional hard capacity so the serving coordinator can
//!   admit sessions against real memory instead of hoping;
//! * [`KvCache::bytes`] reports **resident** bytes (pages actually held),
//!   not the `max_seq` bound.
//!
//! The layout is a pure indexing change: positions are written and read
//! in the same order as the flat cache, so engine outputs are
//! **bit-identical** across page sizes (a flat cache is just the
//! `page = max_seq` special case — asserted by the engine's
//! page-boundary tests).

use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

/// Default positions per KV page (the block-granularity sweet spot the
/// BLaST/BLASST line of work uses for position blocking).
pub const DEFAULT_KV_PAGE: usize = 64;

/// Engine-facing KV layout knobs: positions per page and optional pool
/// capacity (pages). `blast serve --kv-page N --kv-pool-pages M` maps
/// straight onto this.
#[derive(Clone, Copy, Debug)]
pub struct KvOptions {
    /// Positions per page (clamped to the engine's `max_seq`).
    pub page: usize,
    /// Hard pool capacity in pages; `None` = unbounded.
    pub pool_pages: Option<usize>,
}

impl Default for KvOptions {
    fn default() -> Self {
        KvOptions {
            page: DEFAULT_KV_PAGE,
            pool_pages: None,
        }
    }
}

/// Geometry of one cache: model shape + page size. Copied into every
/// [`KvCache`] so kernels can index pages without touching the pool lock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvGeom {
    /// Transformer layers cached.
    pub layers: usize,
    /// Attention heads per layer.
    pub heads: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Positions per page.
    pub page: usize,
}

impl KvGeom {
    /// f32 values in one page: K and V, all layers, all heads, `page`
    /// positions.
    pub fn page_floats(&self) -> usize {
        2 * self.layers * self.heads * self.page * self.head_dim
    }

    /// Bytes of one page.
    pub fn page_bytes(&self) -> usize {
        self.page_floats() * 4
    }

    /// Pages needed to hold `positions` positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page)
    }

    /// Offset of the `(layer, K|V, head)` stripe inside a page
    /// (`which` = 0 for K, 1 for V). The stripe is `page × head_dim`
    /// contiguous floats, position-major.
    #[inline]
    fn stripe(&self, layer: usize, which: usize, head: usize) -> usize {
        ((layer * 2 + which) * self.heads + head) * self.page * self.head_dim
    }
}

struct PoolInner {
    /// Recycled page buffers, ready for reuse without a fresh allocation.
    free: Vec<Box<[f32]>>,
    /// Pages currently held by live caches.
    in_use: usize,
    /// Peak of `in_use` since pool creation.
    high_water: usize,
}

/// Shared page allocator: every session's [`KvCache`] draws from (and
/// returns to) one pool, so resident KV memory is bounded and observable
/// process-wide. Cloneable via `Arc`; all methods take `&self`.
pub struct KvPagePool {
    geom: KvGeom,
    /// Hard capacity in pages; `None` = unbounded (tests, single-session
    /// tools). The serving coordinator uses the bound for admission.
    max_pages: Option<usize>,
    inner: Mutex<PoolInner>,
}

impl KvPagePool {
    /// A pool for the given geometry; `max_pages = None` is unbounded.
    pub fn new(geom: KvGeom, max_pages: Option<usize>) -> Arc<KvPagePool> {
        Arc::new(KvPagePool {
            geom,
            max_pages,
            inner: Mutex::new(PoolInner {
                free: Vec::new(),
                in_use: 0,
                high_water: 0,
            }),
        })
    }

    /// The geometry every page of this pool follows.
    pub fn geom(&self) -> KvGeom {
        self.geom
    }

    /// Hard capacity in pages (`None` = unbounded).
    pub fn capacity_pages(&self) -> Option<usize> {
        self.max_pages
    }

    /// Pages currently held by live caches.
    pub fn pages_in_use(&self) -> usize {
        self.inner.lock().unwrap().in_use
    }

    /// Pages still allocatable right now (`None` = unbounded).
    pub fn available_pages(&self) -> Option<usize> {
        self.max_pages
            .map(|cap| cap.saturating_sub(self.inner.lock().unwrap().in_use))
    }

    /// Peak concurrent pages since pool creation — the number a capacity
    /// planner actually needs.
    pub fn high_water_pages(&self) -> usize {
        self.inner.lock().unwrap().high_water
    }

    /// Bytes resident in live caches right now (in-use pages only; the
    /// recycled free list is idle capacity, not session footprint).
    pub fn resident_bytes(&self) -> usize {
        self.pages_in_use() * self.geom.page_bytes()
    }

    /// Hand out one page, recycling a returned buffer when possible.
    /// Clean error — never a panic — when the pool is at capacity.
    fn alloc(&self) -> Result<Box<[f32]>> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(cap) = self.max_pages {
            if inner.in_use >= cap {
                bail!(
                    "KV page pool exhausted: {} of {cap} pages in use",
                    inner.in_use
                );
            }
        }
        inner.in_use += 1;
        inner.high_water = inner.high_water.max(inner.in_use);
        // Recycled pages keep stale values: every read is bounded by the
        // owning cache's `len`, and every position is written before `len`
        // covers it, so stale floats are never observed.
        let page = inner
            .free
            .pop()
            .unwrap_or_else(|| vec![0.0f32; self.geom.page_floats()].into_boxed_slice());
        Ok(page)
    }

    /// Return a page to the free list (called by [`KvCache`] on drop).
    fn release(&self, page: Box<[f32]>) {
        let mut inner = self.inner.lock().unwrap();
        inner.in_use -= 1;
        inner.free.push(page);
    }
}

/// Per-session KV cache backed by pool pages, allocated on demand as the
/// sequence grows and returned to the pool on drop.
pub struct KvCache {
    pool: Arc<KvPagePool>,
    geom: KvGeom,
    pages: Vec<Box<[f32]>>,
    /// Number of valid positions (same meaning as the seed flat cache).
    pub len: usize,
}

impl KvCache {
    /// An empty cache over `pool`; no pages are held until
    /// [`KvCache::ensure`] is called.
    pub fn new(pool: Arc<KvPagePool>) -> KvCache {
        let geom = pool.geom();
        KvCache {
            pool,
            geom,
            pages: Vec::new(),
            len: 0,
        }
    }

    /// Resident bytes of this cache — pages actually held, **not** the
    /// `max_seq` preallocation bound the seed cache reported.
    pub fn bytes(&self) -> usize {
        self.pages.len() * self.geom.page_bytes()
    }

    /// Pages currently held.
    pub fn pages_held(&self) -> usize {
        self.pages.len()
    }

    /// Positions per page of this cache's layout.
    pub fn page_positions(&self) -> usize {
        self.geom.page
    }

    /// Grow to cover `positions` positions, allocating pages from the
    /// pool on demand. Clean error on pool exhaustion; the cache keeps
    /// the pages it already acquired (its `len` and contents are
    /// untouched either way).
    pub fn ensure(&mut self, positions: usize) -> Result<()> {
        let need = self.geom.pages_for(positions);
        while self.pages.len() < need {
            self.pages.push(self.pool.alloc()?);
        }
        Ok(())
    }

    /// The `(page × hd)` K stripe of `(layer, head)` in page `pi`
    /// (position-major). Positions `pi*page ..` of the sequence.
    #[inline]
    pub fn k_head(&self, layer: usize, head: usize, pi: usize) -> &[f32] {
        let o = self.geom.stripe(layer, 0, head);
        &self.pages[pi][o..o + self.geom.page * self.geom.head_dim]
    }

    /// The `(page × hd)` V stripe of `(layer, head)` in page `pi`.
    #[inline]
    pub fn v_head(&self, layer: usize, head: usize, pi: usize) -> &[f32] {
        let o = self.geom.stripe(layer, 1, head);
        &self.pages[pi][o..o + self.geom.page * self.geom.head_dim]
    }

    /// Write one position's K and V rows for `(layer, head)`. The page
    /// covering `pos` must already exist (see [`KvCache::ensure`]).
    #[inline]
    pub fn write_pos(&mut self, layer: usize, head: usize, pos: usize, k: &[f32], v: &[f32]) {
        let hd = self.geom.head_dim;
        debug_assert_eq!(k.len(), hd);
        debug_assert_eq!(v.len(), hd);
        let (pi, off) = (pos / self.geom.page, pos % self.geom.page);
        let ko = self.geom.stripe(layer, 0, head) + off * hd;
        let vo = self.geom.stripe(layer, 1, head) + off * hd;
        let page = &mut self.pages[pi];
        page[ko..ko + hd].copy_from_slice(k);
        page[vo..vo + hd].copy_from_slice(v);
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        for page in self.pages.drain(..) {
            self.pool.release(page);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(page: usize) -> KvGeom {
        KvGeom {
            layers: 2,
            heads: 3,
            head_dim: 4,
            page,
        }
    }

    #[test]
    fn geometry_math() {
        let g = geom(8);
        assert_eq!(g.page_floats(), 2 * 2 * 3 * 8 * 4);
        assert_eq!(g.page_bytes(), g.page_floats() * 4);
        assert_eq!(g.pages_for(0), 0);
        assert_eq!(g.pages_for(1), 1);
        assert_eq!(g.pages_for(8), 1);
        assert_eq!(g.pages_for(9), 2);
    }

    #[test]
    fn write_then_read_roundtrip_across_pages() {
        let pool = KvPagePool::new(geom(2), None);
        let mut c = KvCache::new(pool);
        c.ensure(5).unwrap();
        assert_eq!(c.pages_held(), 3);
        // distinct values per (layer, head, pos, dim, k/v)
        for li in 0..2 {
            for hh in 0..3 {
                for pos in 0..5 {
                    let base = (li * 1000 + hh * 100 + pos * 10) as f32;
                    let k: Vec<f32> = (0..4).map(|d| base + d as f32).collect();
                    let v: Vec<f32> = (0..4).map(|d| -(base + d as f32)).collect();
                    c.write_pos(li, hh, pos, &k, &v);
                }
            }
        }
        for li in 0..2 {
            for hh in 0..3 {
                for pos in 0..5 {
                    let (pi, off) = (pos / 2, pos % 2);
                    let k = &c.k_head(li, hh, pi)[off * 4..off * 4 + 4];
                    let v = &c.v_head(li, hh, pi)[off * 4..off * 4 + 4];
                    let base = (li * 1000 + hh * 100 + pos * 10) as f32;
                    for d in 0..4 {
                        assert_eq!(k[d], base + d as f32, "K l{li} h{hh} p{pos} d{d}");
                        assert_eq!(v[d], -(base + d as f32), "V l{li} h{hh} p{pos} d{d}");
                    }
                }
            }
        }
    }

    #[test]
    fn pool_counts_and_high_water() {
        let pool = KvPagePool::new(geom(4), Some(4));
        assert_eq!(pool.available_pages(), Some(4));
        let mut a = KvCache::new(pool.clone());
        a.ensure(8).unwrap(); // 2 pages
        let mut b = KvCache::new(pool.clone());
        b.ensure(4).unwrap(); // 1 page
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(pool.available_pages(), Some(1));
        assert_eq!(pool.resident_bytes(), 3 * pool.geom().page_bytes());
        drop(a);
        assert_eq!(pool.pages_in_use(), 1);
        // high water sticks at the peak
        assert_eq!(pool.high_water_pages(), 3);
        // released pages are recycled, not lost
        let mut c2 = KvCache::new(pool.clone());
        c2.ensure(12).unwrap();
        assert_eq!(pool.pages_in_use(), 4);
        assert_eq!(pool.high_water_pages(), 4);
    }

    #[test]
    fn exhaustion_is_a_clean_error_and_keeps_acquired_pages() {
        let pool = KvPagePool::new(geom(2), Some(2));
        let mut c = KvCache::new(pool.clone());
        let err = c.ensure(6).unwrap_err(); // needs 3 pages, cap 2
        assert!(err.to_string().contains("exhausted"), "{err}");
        // the two acquired pages stay with the cache (len untouched)
        assert_eq!(c.pages_held(), 2);
        assert_eq!(c.len, 0);
        // freeing makes the allocation succeed for others
        drop(c);
        let mut d = KvCache::new(pool.clone());
        d.ensure(4).unwrap();
        assert_eq!(pool.pages_in_use(), 2);
    }

    #[test]
    fn bytes_report_resident_pages_only() {
        let pool = KvPagePool::new(geom(8), None);
        let mut c = KvCache::new(pool.clone());
        assert_eq!(c.bytes(), 0);
        c.ensure(1).unwrap();
        assert_eq!(c.bytes(), pool.geom().page_bytes());
        c.ensure(9).unwrap();
        assert_eq!(c.bytes(), 2 * pool.geom().page_bytes());
        // ensure() never shrinks; bytes track pages held
        c.ensure(3).unwrap();
        assert_eq!(c.bytes(), 2 * pool.geom().page_bytes());
    }

    #[test]
    fn zero_capacity_pool_rejects_first_page() {
        let pool = KvPagePool::new(geom(2), Some(0));
        let mut c = KvCache::new(pool);
        assert!(c.ensure(1).is_err());
        assert_eq!(c.pages_held(), 0);
    }
}
