//! Model geometry: native configs (for the engine) and the paper's real
//! model family (for the analytic reproductions of Figs. 5 and 7).

use crate::runtime::ConfigInfo;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gpt2,
    Llama,
    Vit,
}

impl ModelKind {
    pub fn parse(s: &str) -> ModelKind {
        match s {
            "gpt2" => ModelKind::Gpt2,
            "llama" => ModelKind::Llama,
            "vit" => ModelKind::Vit,
            other => panic!("unknown model kind {other:?}"),
        }
    }
}

/// Geometry the native engine runs (usually constructed from the manifest).
#[derive(Clone, Debug)]
pub struct NativeConfig {
    pub name: String,
    pub kind: ModelKind,
    pub vocab: usize,
    pub emb: usize,
    pub ffn: usize,
    pub layers: usize,
    pub heads: usize,
    pub max_seq: usize,
    pub block: usize,
}

impl NativeConfig {
    pub fn from_manifest(c: &ConfigInfo) -> NativeConfig {
        NativeConfig {
            name: c.name.clone(),
            kind: ModelKind::parse(&c.kind),
            vocab: c.vocab,
            emb: c.emb,
            ffn: c.ffn,
            layers: c.layers,
            heads: c.heads,
            max_seq: c.seq,
            block: c.block,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.emb / self.heads
    }

    /// MLP weight matrices per layer (name suffix, rows, cols).
    pub fn mlp_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        match self.kind {
            ModelKind::Llama => vec![
                ("mlp.w1", self.emb, self.ffn),
                ("mlp.w2", self.emb, self.ffn),
                ("mlp.w3", self.ffn, self.emb),
            ],
            _ => vec![
                ("mlp.w1", self.emb, self.ffn),
                ("mlp.w3", self.ffn, self.emb),
            ],
        }
    }

    /// Total parameter count (matches the L2 `param_spec`).
    pub fn param_count(&self) -> usize {
        let e = self.emb;
        let attn = 4 * e * e;
        let mlp: usize = self.mlp_shapes().iter().map(|(_, r, c)| r * c).sum();
        let per_layer = attn + mlp + 2 * e;
        let emb = self.vocab * e
            + if self.kind == ModelKind::Gpt2 {
                self.max_seq * e
            } else {
                0
            };
        emb + self.layers * per_layer + e + e * self.vocab
    }
}

/// Build the full [`ConfigInfo`] (positional parameter ABI, mask spec,
/// MLP-weight list) of one LM twin — the Rust mirror of
/// `python/compile/model.py::_lm_param_spec`, so the native training
/// backend and the AOT graphs agree on names, shapes and order. Public so
/// tests and benches can construct ad-hoc twins; the named catalog is
/// [`sim_config`].
#[allow(clippy::too_many_arguments)] // a geometry record, mirrored from aot.py
pub fn lm_config_info(
    name: &str,
    kind: &str,
    vocab: usize,
    emb: usize,
    ffn: usize,
    layers: usize,
    heads: usize,
    seq: usize,
    batch: usize,
    block: usize,
    lr: f64,
    paper_equiv: &str,
) -> ConfigInfo {
    let (e, f, v) = (emb, ffn, vocab);
    let mut params: Vec<(String, Vec<usize>)> = vec![("tok_emb".into(), vec![v, e])];
    if kind == "gpt2" {
        params.push(("pos_emb".into(), vec![seq, e]));
    }
    let mut mlp_weights = Vec::new();
    for i in 0..layers {
        let p = |s: &str| format!("layer{i}.{s}");
        params.push((p("ln1"), vec![e]));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            params.push((p(w), vec![e, e]));
        }
        params.push((p("ln2"), vec![e]));
        params.push((p("mlp.w1"), vec![e, f]));
        mlp_weights.push(p("mlp.w1"));
        if kind == "llama" {
            params.push((p("mlp.w2"), vec![e, f]));
            mlp_weights.push(p("mlp.w2"));
        }
        params.push((p("mlp.w3"), vec![f, e]));
        mlp_weights.push(p("mlp.w3"));
    }
    params.push(("final_norm".into(), vec![e]));
    params.push(("lm_head".into(), vec![e, v]));
    let masks = mlp_weights
        .iter()
        .map(|n| {
            let shape = params.iter().find(|(pn, _)| pn == n).unwrap().1.clone();
            assert!(shape[0] % block == 0 && shape[1] % block == 0);
            (n.clone(), vec![shape[0] / block, shape[1] / block])
        })
        .collect();
    let param_count = params.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
    ConfigInfo {
        name: name.into(),
        kind: kind.into(),
        vocab,
        emb,
        ffn,
        layers,
        heads,
        head_dim: emb / heads,
        seq,
        batch,
        block,
        num_classes: 0,
        patch_dim: 0,
        lr,
        param_count,
        paper_equiv: paper_equiv.into(),
        params,
        masks,
        mlp_weights,
    }
}

/// Names the built-in twin catalog answers to (see [`sim_config`]).
pub const SIM_CONFIGS: &[&str] = &[
    "micro",
    "micro-llama",
    "gpt2s-sim",
    "gpt2s-sim-b1",
    "gpt2s-sim-b16",
    "llama-sim",
    "e2e-small",
];

/// The built-in LM twin catalog — the same geometries
/// `python/compile/aot.py` registers (`CONFIGS` + `LEARNING_RATES`),
/// reproduced natively so the training path does not need `make
/// artifacts`: `Trainer::new_native` resolves configs here instead of the
/// AOT manifest. ViT/GLUE twins are manifest-only (the classifier trainer
/// stays on the AOT backend).
pub fn sim_config(name: &str) -> Option<ConfigInfo> {
    let c = match name {
        "micro" => lm_config_info("micro", "gpt2", 256, 64, 128, 2, 2, 32, 2, 32, 1e-3, "GPT2-small"),
        "micro-llama" => {
            lm_config_info("micro-llama", "llama", 256, 64, 128, 2, 2, 32, 2, 32, 1e-3, "Llama-3.2-1B")
        }
        "gpt2s-sim" => {
            lm_config_info("gpt2s-sim", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 32, 6e-4, "GPT2-small")
        }
        "gpt2s-sim-b1" => {
            lm_config_info("gpt2s-sim-b1", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 1, 6e-4, "GPT2-small")
        }
        "gpt2s-sim-b16" => {
            lm_config_info("gpt2s-sim-b16", "gpt2", 2048, 256, 1024, 4, 4, 128, 8, 16, 6e-4, "GPT2-small")
        }
        "llama-sim" => {
            lm_config_info("llama-sim", "llama", 2048, 256, 1024, 4, 4, 128, 8, 32, 6e-4, "Llama-3.2-1B")
        }
        "e2e-small" => {
            lm_config_info("e2e-small", "gpt2", 4096, 512, 2048, 8, 8, 256, 4, 64, 3e-4, "GPT2-medium")
        }
        _ => return None,
    };
    Some(c)
}

/// A real model geometry from the paper's evaluation (Figs. 5/7).
#[derive(Clone, Debug)]
pub struct PaperGeometry {
    pub name: &'static str,
    pub emb: usize,
    pub ffn: usize,
    pub layers: usize,
    /// Total parameters (billions) as reported publicly.
    pub params_b: f64,
    /// Llama-style (3 MLP matrices) vs GPT-2-style (2).
    pub swiglu: bool,
}

impl PaperGeometry {
    /// MLP parameters per layer.
    pub fn mlp_params_per_layer(&self) -> usize {
        let mats = if self.swiglu { 3 } else { 2 };
        mats * self.emb * self.ffn
    }

    /// Total MLP parameters.
    pub fn mlp_params(&self) -> usize {
        self.layers * self.mlp_params_per_layer()
    }

    /// Total parameters (from the headline count).
    pub fn total_params(&self) -> f64 {
        self.params_b * 1e9
    }

    /// FLOPs of one MLP block application per token (dense).
    pub fn mlp_flops_per_token(&self) -> f64 {
        2.0 * self.mlp_params_per_layer() as f64
    }
}

/// The model family of Figs. 1, 5 and 7.
pub fn paper_catalog() -> Vec<PaperGeometry> {
    vec![
        PaperGeometry { name: "Llama-3.2-1B", emb: 2048, ffn: 8192, layers: 16, params_b: 1.24, swiglu: true },
        PaperGeometry { name: "Llama-3.2-3B", emb: 3072, ffn: 8192, layers: 28, params_b: 3.21, swiglu: true },
        PaperGeometry { name: "Llama-3.1-8B", emb: 4096, ffn: 14336, layers: 32, params_b: 8.03, swiglu: true },
        PaperGeometry { name: "Llama-3.1-70B", emb: 8192, ffn: 28672, layers: 80, params_b: 70.6, swiglu: true },
        PaperGeometry { name: "Llama-3.1-405B", emb: 16384, ffn: 53248, layers: 126, params_b: 405.0, swiglu: true },
        PaperGeometry { name: "GPT2-small", emb: 768, ffn: 3072, layers: 12, params_b: 0.124, swiglu: false },
        PaperGeometry { name: "GPT2-medium", emb: 1024, ffn: 4096, layers: 24, params_b: 0.355, swiglu: false },
        PaperGeometry { name: "GPT2-large", emb: 1280, ffn: 5120, layers: 36, params_b: 0.774, swiglu: false },
        PaperGeometry { name: "GPT2-XL", emb: 1600, ffn: 6400, layers: 48, params_b: 1.44, swiglu: false },
        PaperGeometry { name: "ViT-B/16", emb: 768, ffn: 3072, layers: 12, params_b: 0.086, swiglu: false },
        PaperGeometry { name: "ViT-L/16", emb: 1024, ffn: 4096, layers: 24, params_b: 0.307, swiglu: false },
    ]
}

pub fn paper_geometry(name: &str) -> PaperGeometry {
    paper_catalog()
        .into_iter()
        .find(|g| g.name == name)
        .unwrap_or_else(|| panic!("unknown paper geometry {name:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_sane() {
        let cat = paper_catalog();
        assert_eq!(cat.len(), 11);
        let l405 = paper_geometry("Llama-3.1-405B");
        // MLP weights dominate at 405B scale
        assert!(l405.mlp_params() as f64 > 0.7 * l405.total_params());
        let g = paper_geometry("GPT2-small");
        assert_eq!(g.mlp_params_per_layer(), 2 * 768 * 3072);
    }

    #[test]
    fn sim_catalog_matches_aot_geometry() {
        for name in SIM_CONFIGS {
            let c = sim_config(name).unwrap();
            assert_eq!(&c.name, name);
            // every mask grid divides its weight and the mlp list is in
            // ABI (layer) order
            for (mname, shape) in &c.masks {
                let w = c.param_shape(mname).unwrap();
                assert_eq!(shape[0] * c.block, w[0], "{name}/{mname}");
                assert_eq!(shape[1] * c.block, w[1], "{name}/{mname}");
            }
            let per_layer = if c.kind == "llama" { 3 } else { 2 };
            assert_eq!(c.mlp_weights.len(), per_layer * c.layers, "{name}");
            // ParamStore::init consumes this spec directly
            let s = crate::model::params::ParamStore::init(&c, 1);
            assert_eq!(s.len(), c.params.len());
            assert_eq!(s.total_elements(), c.param_count);
        }
        // the micro twin's geometry is pinned (aot.py: 256/64/128/2/2/32/2/32)
        let m = sim_config("micro").unwrap();
        assert_eq!((m.vocab, m.emb, m.ffn), (256, 64, 128));
        assert_eq!((m.layers, m.heads, m.seq, m.batch, m.block), (2, 2, 32, 2, 32));
        assert!(sim_config("vit-sim").is_none());
    }

    #[test]
    fn native_param_count_matches_micro_manifest_value() {
        // micro: gpt2, vocab 256, emb 64, ffn 128, layers 2, seq 32
        let c = NativeConfig {
            name: "micro".into(),
            kind: ModelKind::Gpt2,
            vocab: 256,
            emb: 64,
            ffn: 128,
            layers: 2,
            heads: 2,
            max_seq: 32,
            block: 32,
        };
        // tok 256*64 + pos 32*64 + 2*(4*64*64 + 2*64*128 + 2*64) + 64 + 64*256
        let want = 256 * 64 + 32 * 64 + 2 * (4 * 64 * 64 + 2 * 64 * 128 + 128) + 64 + 64 * 256;
        assert_eq!(c.param_count(), want);
    }
}
