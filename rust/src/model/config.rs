//! Model geometry: native configs (for the engine) and the paper's real
//! model family (for the analytic reproductions of Figs. 5 and 7).

use crate::runtime::ConfigInfo;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gpt2,
    Llama,
    Vit,
}

impl ModelKind {
    pub fn parse(s: &str) -> ModelKind {
        match s {
            "gpt2" => ModelKind::Gpt2,
            "llama" => ModelKind::Llama,
            "vit" => ModelKind::Vit,
            other => panic!("unknown model kind {other:?}"),
        }
    }
}

/// Geometry the native engine runs (usually constructed from the manifest).
#[derive(Clone, Debug)]
pub struct NativeConfig {
    pub name: String,
    pub kind: ModelKind,
    pub vocab: usize,
    pub emb: usize,
    pub ffn: usize,
    pub layers: usize,
    pub heads: usize,
    pub max_seq: usize,
    pub block: usize,
}

impl NativeConfig {
    pub fn from_manifest(c: &ConfigInfo) -> NativeConfig {
        NativeConfig {
            name: c.name.clone(),
            kind: ModelKind::parse(&c.kind),
            vocab: c.vocab,
            emb: c.emb,
            ffn: c.ffn,
            layers: c.layers,
            heads: c.heads,
            max_seq: c.seq,
            block: c.block,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.emb / self.heads
    }

    /// MLP weight matrices per layer (name suffix, rows, cols).
    pub fn mlp_shapes(&self) -> Vec<(&'static str, usize, usize)> {
        match self.kind {
            ModelKind::Llama => vec![
                ("mlp.w1", self.emb, self.ffn),
                ("mlp.w2", self.emb, self.ffn),
                ("mlp.w3", self.ffn, self.emb),
            ],
            _ => vec![
                ("mlp.w1", self.emb, self.ffn),
                ("mlp.w3", self.ffn, self.emb),
            ],
        }
    }

    /// Total parameter count (matches the L2 `param_spec`).
    pub fn param_count(&self) -> usize {
        let e = self.emb;
        let attn = 4 * e * e;
        let mlp: usize = self.mlp_shapes().iter().map(|(_, r, c)| r * c).sum();
        let per_layer = attn + mlp + 2 * e;
        let emb = self.vocab * e
            + if self.kind == ModelKind::Gpt2 {
                self.max_seq * e
            } else {
                0
            };
        emb + self.layers * per_layer + e + e * self.vocab
    }
}

/// A real model geometry from the paper's evaluation (Figs. 5/7).
#[derive(Clone, Debug)]
pub struct PaperGeometry {
    pub name: &'static str,
    pub emb: usize,
    pub ffn: usize,
    pub layers: usize,
    /// Total parameters (billions) as reported publicly.
    pub params_b: f64,
    /// Llama-style (3 MLP matrices) vs GPT-2-style (2).
    pub swiglu: bool,
}

impl PaperGeometry {
    /// MLP parameters per layer.
    pub fn mlp_params_per_layer(&self) -> usize {
        let mats = if self.swiglu { 3 } else { 2 };
        mats * self.emb * self.ffn
    }

    /// Total MLP parameters.
    pub fn mlp_params(&self) -> usize {
        self.layers * self.mlp_params_per_layer()
    }

    /// Total parameters (from the headline count).
    pub fn total_params(&self) -> f64 {
        self.params_b * 1e9
    }

    /// FLOPs of one MLP block application per token (dense).
    pub fn mlp_flops_per_token(&self) -> f64 {
        2.0 * self.mlp_params_per_layer() as f64
    }
}

/// The model family of Figs. 1, 5 and 7.
pub fn paper_catalog() -> Vec<PaperGeometry> {
    vec![
        PaperGeometry { name: "Llama-3.2-1B", emb: 2048, ffn: 8192, layers: 16, params_b: 1.24, swiglu: true },
        PaperGeometry { name: "Llama-3.2-3B", emb: 3072, ffn: 8192, layers: 28, params_b: 3.21, swiglu: true },
        PaperGeometry { name: "Llama-3.1-8B", emb: 4096, ffn: 14336, layers: 32, params_b: 8.03, swiglu: true },
        PaperGeometry { name: "Llama-3.1-70B", emb: 8192, ffn: 28672, layers: 80, params_b: 70.6, swiglu: true },
        PaperGeometry { name: "Llama-3.1-405B", emb: 16384, ffn: 53248, layers: 126, params_b: 405.0, swiglu: true },
        PaperGeometry { name: "GPT2-small", emb: 768, ffn: 3072, layers: 12, params_b: 0.124, swiglu: false },
        PaperGeometry { name: "GPT2-medium", emb: 1024, ffn: 4096, layers: 24, params_b: 0.355, swiglu: false },
        PaperGeometry { name: "GPT2-large", emb: 1280, ffn: 5120, layers: 36, params_b: 0.774, swiglu: false },
        PaperGeometry { name: "GPT2-XL", emb: 1600, ffn: 6400, layers: 48, params_b: 1.44, swiglu: false },
        PaperGeometry { name: "ViT-B/16", emb: 768, ffn: 3072, layers: 12, params_b: 0.086, swiglu: false },
        PaperGeometry { name: "ViT-L/16", emb: 1024, ffn: 4096, layers: 24, params_b: 0.307, swiglu: false },
    ]
}

pub fn paper_geometry(name: &str) -> PaperGeometry {
    paper_catalog()
        .into_iter()
        .find(|g| g.name == name)
        .unwrap_or_else(|| panic!("unknown paper geometry {name:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_sane() {
        let cat = paper_catalog();
        assert_eq!(cat.len(), 11);
        let l405 = paper_geometry("Llama-3.1-405B");
        // MLP weights dominate at 405B scale
        assert!(l405.mlp_params() as f64 > 0.7 * l405.total_params());
        let g = paper_geometry("GPT2-small");
        assert_eq!(g.mlp_params_per_layer(), 2 * 768 * 3072);
    }

    #[test]
    fn native_param_count_matches_micro_manifest_value() {
        // micro: gpt2, vocab 256, emb 64, ffn 128, layers 2, seq 32
        let c = NativeConfig {
            name: "micro".into(),
            kind: ModelKind::Gpt2,
            vocab: 256,
            emb: 64,
            ffn: 128,
            layers: 2,
            heads: 2,
            max_seq: 32,
            block: 32,
        };
        // tok 256*64 + pos 32*64 + 2*(4*64*64 + 2*64*128 + 2*64) + 64 + 64*256
        let want = 256 * 64 + 32 * 64 + 2 * (4 * 64 * 64 + 2 * 64 * 128 + 128) + 64 + 64 * 256;
        assert_eq!(c.param_count(), want);
    }
}
