//! Native block-sparse inference engine.
//!
//! Runs the same Transformer the L2 JAX model defines, but entirely on the
//! native kernel stack, with the MLP weights in either dense (GEMM) or
//! BCSC (BSpMM) form. This is the component behind the paper's Fig. 6:
//! identical weights + masks, two execution modes, and the wall-clock gap
//! between them is the end-to-end inference speedup of block sparsity.
//!
//! Sessions are per-sequence (each owns a paged [`KvCache`] drawing from
//! the engine's shared [`KvPagePool`]) over shared weights. The serving
//! coordinator multiplexes many sessions and drives each decode round
//! either one session at a time ([`Engine::decode`], a chain of 1-row
//! GEMVs) or — the throughput path — as one [`Engine::decode_batch`]
//! call that stacks the B active sessions' hidden states into a single
//! `(B × d_model)` activation matrix, so every projection, MLP and the LM
//! head run as one packed GEMM/BSpMM over the prepacked weights. Attention
//! stays per-sequence (each session has its own cache and position) and is
//! parallelized across `(session, head)` items on the thread pool,
//! cost-weighted by each session's position (long sessions cost more per
//! head). Both paths share per-row arithmetic and summation order, so
//! greedy decode streams are **bit-identical** batched vs sequential —
//! and KV page size is a pure layout knob, so they are also bit-identical
//! across page sizes (the flat cache is `page = max_seq`).
//!
//! All dense weight matrices (attention projections, LM head, dense-mode
//! MLP weights) are packed into [`PackedB`] panel form **once at engine
//! build time**, so every prefill and decode projection runs the packed
//! micro-kernel without any per-call packing sweep; dense-MLP hidden
//! buffers come from the thread-local scratch arena instead of per-call
//! allocations.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::kernels::attention::{
    causal_attention_offset_thresh, causal_attention_thresh, decode_head_paged_into,
    decode_head_paged_thresh_into, AttnCounters, AttnThreshold,
};
use crate::kernels::bspmm::{fused_mlp_sparse, gelu_mlp_sparse, FusedMlpWeights};
use crate::kernels::gemm::{gemm_packed_ep_into, gemm_packed_into};
use crate::kernels::ops;
use crate::kernels::pack::PackedB;
use crate::kernels::simd::Epilogue;
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::kv::{KvGeom, KvOptions, KvPagePool};
use crate::model::params::ParamStore;
use crate::sparse::{Bcsc, BlockMask};
use crate::tensor::Tensor;
use crate::util::{scratch, threadpool};

pub use crate::kernels::attention::AttnStats;
pub use crate::model::kv::KvCache;

/// BLASST dynamic attention sparsity knobs (see
/// [`crate::kernels::attention`]): `threshold = None` (the default) is
/// exact attention, bit-identical to an engine built before the knob
/// existed; `Some(τ)` arms the k-tile / KV-page skip rule — everything
/// skipped carries post-softmax mass ≤ count·e^(−τ). `blast serve
/// --attn-threshold τ` maps straight onto this.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AttnOptions {
    /// Skip threshold τ; must be finite and ≥ 0 (validated at engine
    /// build). `None` = exact.
    pub threshold: Option<f32>,
}

/// MLP execution mode (the Fig. 6 switch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpMode {
    /// Masked weights stored dense, multiplied with the dense GEMM — the
    /// baseline (what a dense-only runtime would do).
    Dense,
    /// Masked weights stored in BCSC, multiplied with BSpMM + fused
    /// nonlinearity — the paper's kernel.
    Sparse,
}

enum MlpWeights {
    DenseSwiglu { w1: PackedB, w2: PackedB, w3: PackedB },
    DenseGelu { w1: PackedB, w3: PackedB },
    SparseSwiglu { w1: Bcsc, w2: Bcsc, w3: Bcsc },
    SparseGelu { w1: Bcsc, w3: Bcsc },
}

struct LayerWeights {
    ln1: Vec<f32>,
    wq: PackedB,
    wk: PackedB,
    wv: PackedB,
    wo: PackedB,
    ln2: Vec<f32>,
    mlp: MlpWeights,
}

/// The immutable, prepacked half of an engine: embeddings, packed
/// projection/LM-head panels and per-layer MLP weights. Packing runs once
/// at build time; every engine forked from the same build shares this
/// through one `Arc`, so a fleet replica restart ([`Engine::fork_with_fresh_kv`])
/// costs a pool allocation, not a re-pack of the whole model.
struct EngineWeights {
    mode: MlpMode,
    tok_emb: Tensor,
    pos_emb: Option<Tensor>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
    lm_head: PackedB,
}

/// The native block-sparse inference engine: shared prepacked weights
/// ([`EngineWeights`], per-layer MLP weights in dense [`PackedB`] or
/// sparse [`Bcsc`] form depending on [`MlpMode`]) plus the
/// [`KvPagePool`] every session's cache draws pages from. The pool is
/// per-engine state: forked engines share weights but never pages.
pub struct Engine {
    cfg: NativeConfig,
    w: Arc<EngineWeights>,
    kv_pool: Arc<KvPagePool>,
    attn: AttnOptions,
    /// Cumulative BLASST skip counters — per engine, so every fleet
    /// replica reports its own (`fork_with_fresh_kv` starts fresh ones).
    attn_counters: Arc<AttnCounters>,
}

/// Masked dense weight, packed once into micro-kernel panel form.
fn masked_packed(
    params: &ParamStore,
    masks: &BTreeMap<String, BlockMask>,
    name: &str,
    block: usize,
) -> PackedB {
    let mut t = params.req(name).clone();
    if let Some(m) = masks.get(name) {
        m.apply_to(t.data_mut(), block);
    }
    PackedB::pack(t.data(), t.rows(), t.cols())
}

/// Unmasked dense weight (projections), packed once.
fn packed(params: &ParamStore, name: &str) -> PackedB {
    let t = params.req(name);
    PackedB::pack(t.data(), t.rows(), t.cols())
}

fn bcsc_of(params: &ParamStore, masks: &BTreeMap<String, BlockMask>, name: &str, block: usize) -> Bcsc {
    let t = params.req(name);
    let full;
    let mask = match masks.get(name) {
        Some(m) => m,
        None => {
            full = BlockMask::ones(t.rows() / block, t.cols() / block);
            &full
        }
    };
    Bcsc::from_dense(t, mask, block)
}

impl Engine {
    /// Build an engine over trained parameters + masks, with the default
    /// KV layout ([`KvOptions::default`]: 64-position pages, unbounded
    /// pool).
    pub fn new(
        cfg: NativeConfig,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        mode: MlpMode,
    ) -> Result<Engine> {
        Engine::new_with_kv(cfg, params, masks, mode, KvOptions::default())
    }

    /// Build an engine with an explicit KV layout: `kv.page` positions
    /// per page (clamped to `max_seq`) and an optional hard pool capacity
    /// in pages. Page size is a pure layout knob — outputs are
    /// bit-identical across page sizes.
    pub fn new_with_kv(
        cfg: NativeConfig,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        mode: MlpMode,
        kv: KvOptions,
    ) -> Result<Engine> {
        Engine::new_with_opts(cfg, params, masks, mode, kv, AttnOptions::default())
    }

    /// [`Engine::new_with_kv`] plus the BLASST attention knobs. An armed
    /// threshold also arms K norm stamping on the KV pool (so paged
    /// decode can skip pages by score bound); `AttnOptions::default()`
    /// is byte-for-byte [`Engine::new_with_kv`].
    pub fn new_with_opts(
        cfg: NativeConfig,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        mode: MlpMode,
        kv: KvOptions,
        attn: AttnOptions,
    ) -> Result<Engine> {
        if kv.page == 0 {
            bail!("KV page size must be >= 1 position");
        }
        if let Some(tau) = attn.threshold {
            // NaN or negative τ would silently turn the skip test into
            // garbage (NaN compares false everywhere; negative skips
            // tiles *above* the running max) — reject at build time
            if !tau.is_finite() || tau < 0.0 {
                bail!("attention threshold must be a finite value >= 0, got {tau}");
            }
        }
        if cfg.kind == ModelKind::Vit {
            bail!("the autoregressive engine serves LM configs; use the eval drivers for ViT");
        }
        let b = cfg.block;
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let p = |s: &str| format!("layer{i}.{s}");
            let mlp = match (cfg.kind, mode) {
                (ModelKind::Llama, MlpMode::Dense) => MlpWeights::DenseSwiglu {
                    w1: masked_packed(params, masks, &p("mlp.w1"), b),
                    w2: masked_packed(params, masks, &p("mlp.w2"), b),
                    w3: masked_packed(params, masks, &p("mlp.w3"), b),
                },
                (ModelKind::Llama, MlpMode::Sparse) => MlpWeights::SparseSwiglu {
                    w1: bcsc_of(params, masks, &p("mlp.w1"), b),
                    w2: bcsc_of(params, masks, &p("mlp.w2"), b),
                    w3: bcsc_of(params, masks, &p("mlp.w3"), b),
                },
                (_, MlpMode::Dense) => MlpWeights::DenseGelu {
                    w1: masked_packed(params, masks, &p("mlp.w1"), b),
                    w3: masked_packed(params, masks, &p("mlp.w3"), b),
                },
                (_, MlpMode::Sparse) => MlpWeights::SparseGelu {
                    w1: bcsc_of(params, masks, &p("mlp.w1"), b),
                    w3: bcsc_of(params, masks, &p("mlp.w3"), b),
                },
            };
            layers.push(LayerWeights {
                ln1: params.req(&p("ln1")).data().to_vec(),
                wq: packed(params, &p("attn.wq")),
                wk: packed(params, &p("attn.wk")),
                wv: packed(params, &p("attn.wv")),
                wo: packed(params, &p("attn.wo")),
                ln2: params.req(&p("ln2")).data().to_vec(),
                mlp,
            });
        }
        let geom = KvGeom {
            layers: cfg.layers,
            heads: cfg.heads,
            head_dim: cfg.head_dim(),
            page: kv.page.min(cfg.max_seq),
        };
        Ok(Engine {
            w: Arc::new(EngineWeights {
                mode,
                tok_emb: params.req("tok_emb").clone(),
                pos_emb: params.get("pos_emb").cloned(),
                layers,
                final_norm: params.req("final_norm").data().to_vec(),
                lm_head: packed(params, "lm_head"),
            }),
            kv_pool: KvPagePool::new_with_stamping(
                geom,
                kv.pool_pages,
                kv.prefix_cache,
                attn.threshold.is_some(),
            ),
            cfg,
            attn,
            attn_counters: Arc::new(AttnCounters::new()),
        })
    }

    /// A new engine over the **same prepacked weights** but a fresh, empty
    /// [`KvPagePool`] with the original geometry, capacity and
    /// prefix-cache setting. This is the replica-restart path: weights are
    /// shared through the `Arc` (no re-pack, no copy), while KV state —
    /// pages, prefix index, high-water marks — starts from zero, exactly
    /// as if the process had restarted with warm weights.
    pub fn fork_with_fresh_kv(&self) -> Engine {
        Engine {
            cfg: self.cfg.clone(),
            w: self.w.clone(),
            kv_pool: KvPagePool::new_with_stamping(
                self.kv_pool.geom(),
                self.kv_pool.capacity_pages(),
                self.kv_pool.prefix_enabled(),
                self.kv_pool.stamping_enabled(),
            ),
            attn: self.attn,
            // fresh counters: each replica incarnation reports its own
            // skip totals, like its fresh KV pool
            attn_counters: Arc::new(AttnCounters::new()),
        }
    }

    /// The geometry this engine was built for.
    pub fn config(&self) -> &NativeConfig {
        &self.cfg
    }

    /// Dense or sparse MLP execution (fixed at build time).
    pub fn mode(&self) -> MlpMode {
        self.w.mode
    }

    /// The BLASST attention options this engine was built with.
    pub fn attn_options(&self) -> AttnOptions {
        self.attn
    }

    /// Armed skip threshold τ (`None` = exact attention).
    pub fn attn_threshold(&self) -> Option<f32> {
        self.attn.threshold
    }

    /// Snapshot of the cumulative skip counters (all zero on an exact
    /// engine — only armed kernel paths count).
    pub fn attn_stats(&self) -> AttnStats {
        self.attn_counters.snapshot()
    }

    /// The armed threshold handle kernels take, or `None` for the exact
    /// paths.
    fn attn_th(&self) -> Option<AttnThreshold<'_>> {
        self.attn
            .threshold
            .map(|tau| AttnThreshold { tau, counters: &self.attn_counters })
    }

    /// Weight bytes resident for the MLP blocks in the current mode — the
    /// per-model input to the Fig. 7 memory model.
    pub fn mlp_weight_bytes(&self) -> usize {
        self.w.layers
            .iter()
            .map(|l| match &l.mlp {
                MlpWeights::DenseSwiglu { w1, w2, w3 } => w1.bytes() + w2.bytes() + w3.bytes(),
                MlpWeights::DenseGelu { w1, w3 } => w1.bytes() + w3.bytes(),
                MlpWeights::SparseSwiglu { w1, w2, w3 } => w1.bytes() + w2.bytes() + w3.bytes(),
                MlpWeights::SparseGelu { w1, w3 } => w1.bytes() + w3.bytes(),
            })
            .sum()
    }

    /// An empty paged KV cache over this engine's pool. Pages are
    /// allocated as the session grows (prefill/decode), so a fresh cache
    /// holds zero bytes; allocation failures surface as clean errors from
    /// those calls, never from here.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.kv_pool.clone())
    }

    /// The shared KV page pool (admission control, metrics).
    pub fn kv_pool(&self) -> &Arc<KvPagePool> {
        &self.kv_pool
    }

    /// Positions per KV page of this engine's layout.
    pub fn kv_page(&self) -> usize {
        self.kv_pool.geom().page
    }

    /// Pages one session needs to hold `positions` positions.
    pub fn kv_pages_for(&self, positions: usize) -> usize {
        self.kv_pool.geom().pages_for(positions)
    }

    /// Bytes the seed flat cache preallocated per session
    /// (`2 × layers × heads × max_seq × hd × 4`) — the bound paged
    /// residency is measured against in `BENCH_attention.json` and the
    /// serve summaries.
    pub fn flat_kv_bytes(&self) -> usize {
        2 * self.cfg.layers * self.cfg.heads * self.cfg.max_seq * self.cfg.head_dim() * 4
    }

    fn norm(&self, x: &[f32], g: &[f32], out: &mut [f32]) {
        match self.cfg.kind {
            ModelKind::Llama => ops::rmsnorm(x, g, out, 1e-5),
            _ => ops::layernorm(x, g, out, 1e-5),
        }
    }

    fn mlp(&self, x: &Tensor, l: &LayerWeights) -> Tensor {
        match &l.mlp {
            MlpWeights::SparseSwiglu { w1, w2, w3 } => {
                fused_mlp_sparse(x, &FusedMlpWeights { w1, w2, w3 })
            }
            MlpWeights::SparseGelu { w1, w3 } => gelu_mlp_sparse(x, w1, w3),
            MlpWeights::DenseSwiglu { w1, w2, w3 } => {
                let m = x.rows();
                let (e, f) = (w1.k, w1.n);
                // scratch-arena hidden tiles: no per-call allocation. The
                // up-projection runs first; the gate projection then
                // carries the SwiGLU epilogue in its write-back, so the
                // old full-tensor `silu(h1)*h2` pass is gone.
                let mut h1 = scratch::take_zeroed(m * f);
                let mut h2 = scratch::take_zeroed(m * f);
                gemm_packed_into(x.data(), w2, &mut h2, m);
                gemm_packed_ep_into(
                    x.data(),
                    w1,
                    &mut h1,
                    m,
                    Epilogue::SiluGate { g: &h2, ldg: f },
                );
                let mut y = Tensor::zeros(&[m, e]);
                gemm_packed_into(&h1, w3, y.data_mut(), m);
                y
            }
            MlpWeights::DenseGelu { w1, w3 } => {
                let m = x.rows();
                let (e, f) = (w1.k, w1.n);
                let mut h = scratch::take_zeroed(m * f);
                // GeLU fused into the up-projection write-back
                gemm_packed_ep_into(x.data(), w1, &mut h, m, Epilogue::Gelu);
                let mut y = Tensor::zeros(&[m, e]);
                gemm_packed_into(&h, w3, y.data_mut(), m);
                y
            }
        }
    }

    /// (seq, e) row-major → (heads, seq, hd) head-major.
    fn split_heads(&self, x: &[f32], seq: usize) -> Vec<f32> {
        let (h, hd, e) = (self.cfg.heads, self.cfg.head_dim(), self.cfg.emb);
        let mut out = vec![0.0f32; seq * e];
        for s in 0..seq {
            for hh in 0..h {
                out[hh * seq * hd + s * hd..hh * seq * hd + (s + 1) * hd]
                    .copy_from_slice(&x[s * e + hh * hd..s * e + (hh + 1) * hd]);
            }
        }
        out
    }

    /// Prompt pass: fills `cache` for positions `0..tokens.len()` and
    /// returns the logits of the last position. Allocates the covering KV
    /// pages up front, so pool exhaustion is a clean error before any
    /// cache state changes.
    ///
    /// With the pool's prefix cache armed (see
    /// [`KvOptions::prefix_cache`]), an empty cache first maps every
    /// prompt page already resident in the pool's prefix index
    /// ([`KvCache::attach_prefix`]) and resumes the pass from the first
    /// unshared position — a cache-hit prompt computes only its tail.
    /// When the *whole* prompt is resident, the last position is
    /// recomputed (into a private copy-on-write page) so the returned
    /// logits always come from a full forward of at least one row. Either
    /// way the logits are **bit-identical** to the unshared pass, and a
    /// successful prefill publishes its own full prompt pages back into
    /// the index. With the prefix cache off this is byte-for-byte the
    /// plain pass.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Result<Vec<f32>> {
        let seq = tokens.len();
        if seq == 0 || seq > self.cfg.max_seq {
            bail!("prompt length {seq} out of range 1..={}", self.cfg.max_seq);
        }
        let matched = cache.attach_prefix(tokens);
        let logits = if matched == 0 {
            self.prefill_full(tokens, cache)?
        } else {
            let mut r0 = matched * self.kv_page();
            if r0 == seq {
                // full hit: recompute the last position so the forward
                // still produces logits; its write lands in a CoW copy
                r0 = seq - 1;
            }
            self.prefill_resume(tokens, cache, r0)?
        };
        cache.register_prefix(tokens);
        Ok(logits)
    }

    /// The unshared prompt pass (every position computed). Pages the
    /// cache may still hold from an earlier pass are copy-on-written
    /// before the K/V stores if anything else references them — a no-op
    /// on the fresh caches every production caller passes, and always a
    /// no-op with the prefix cache off.
    fn prefill_full(&self, tokens: &[u32], cache: &mut KvCache) -> Result<Vec<f32>> {
        let seq = tokens.len();
        cache.ensure(seq)?;
        for pi in 0..self.kv_pages_for(seq) {
            cache.make_private(pi)?;
        }
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        // embed
        let mut x = Tensor::zeros(&[seq, e]);
        for (s, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.cfg.vocab {
                bail!("token {t} out of vocab {}", self.cfg.vocab);
            }
            x.row_mut(s).copy_from_slice(self.w.tok_emb.row(t));
            if let Some(pe) = &self.w.pos_emb {
                for (a, &b) in x.row_mut(s).iter_mut().zip(pe.row(s)) {
                    *a += b;
                }
            }
        }

        let mut xn = Tensor::zeros(&[seq, e]);
        for (li, l) in self.w.layers.iter().enumerate() {
            // pre-norm
            for s in 0..seq {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln1, nr);
            }
            // projections
            let mut q = Tensor::zeros(&[seq, e]);
            let mut k = Tensor::zeros(&[seq, e]);
            let mut v = Tensor::zeros(&[seq, e]);
            gemm_packed_into(xn.data(), &l.wq, q.data_mut(), seq);
            gemm_packed_into(xn.data(), &l.wk, k.data_mut(), seq);
            gemm_packed_into(xn.data(), &l.wv, v.data_mut(), seq);
            let mut qh = self.split_heads(q.data(), seq);
            let mut kh = self.split_heads(k.data(), seq);
            let vh = self.split_heads(v.data(), seq);
            if self.cfg.kind == ModelKind::Llama {
                for hh in 0..h {
                    for s in 0..seq {
                        let o = hh * seq * hd + s * hd;
                        ops::rope_inplace(&mut qh[o..o + hd], s, 10000.0);
                        ops::rope_inplace(&mut kh[o..o + hd], s, 10000.0);
                    }
                }
            }
            // stash K/V into the cache pages (head-major within each page)
            for hh in 0..h {
                for s in 0..seq {
                    let src = hh * seq * hd + s * hd;
                    cache.write_pos(li, hh, s, &kh[src..src + hd], &vh[src..src + hd]);
                }
            }
            let att = causal_attention_thresh(&qh, &kh, &vh, h, seq, hd, self.attn_th());
            let mut proj = Tensor::zeros(&[seq, e]);
            gemm_packed_into(&att, &l.wo, proj.data_mut(), seq);
            x.add_inplace(&proj);
            // MLP
            for s in 0..seq {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln2, nr);
            }
            let y = self.mlp(&xn, l);
            x.add_inplace(&y);
        }
        cache.len = seq;
        // final norm + head for the last position only
        let mut last = vec![0.0f32; e];
        self.norm(x.row(seq - 1), &self.w.final_norm, &mut last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm_packed_into(&last, &self.w.lm_head, &mut logits, 1);
        Ok(logits)
    }

    /// Resume a prompt pass from position `r0`: positions `0..r0` are
    /// already resident in `cache` (pages mapped from the prefix index),
    /// so only rows `r0..seq` are embedded and pushed through the layers,
    /// attending over the full K/V gathered from the cache pages.
    ///
    /// Bit-identity with [`Engine::prefill_full`] holds row by row: every
    /// non-attention op (norms, projections, RoPE, MLP, residual) is
    /// per-row with a summation order independent of how many rows share
    /// the call, shared K/V bits equal what this session would have
    /// computed (same tokens, same weights, deterministic kernels), and
    /// [`causal_attention_offset`] reproduces the full tiling's bits (see
    /// its docs). `r0` must be page-aligned or `seq − 1` (the full-hit
    /// recompute), so at most the page covering `r0` needs a
    /// copy-on-write before the K/V stores.
    fn prefill_resume(&self, tokens: &[u32], cache: &mut KvCache, r0: usize) -> Result<Vec<f32>> {
        let seq = tokens.len();
        let rn = seq - r0;
        cache.ensure(seq)?;
        // first written page may be shared (always is on a full hit);
        // later written pages are freshly allocated, hence private
        cache.make_private(r0 / self.kv_page())?;
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        let page = self.kv_page();
        let n_pages = self.kv_pages_for(seq);
        // embed the tail rows at their global positions
        let mut x = Tensor::zeros(&[rn, e]);
        for (s, &t) in tokens[r0..].iter().enumerate() {
            let t = t as usize;
            if t >= self.cfg.vocab {
                bail!("token {t} out of vocab {}", self.cfg.vocab);
            }
            x.row_mut(s).copy_from_slice(self.w.tok_emb.row(t));
            if let Some(pe) = &self.w.pos_emb {
                for (a, &b) in x.row_mut(s).iter_mut().zip(pe.row(r0 + s)) {
                    *a += b;
                }
            }
        }

        let mut xn = Tensor::zeros(&[rn, e]);
        for (li, l) in self.w.layers.iter().enumerate() {
            // pre-norm
            for s in 0..rn {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln1, nr);
            }
            // projections over the tail rows only
            let mut q = Tensor::zeros(&[rn, e]);
            let mut k = Tensor::zeros(&[rn, e]);
            let mut v = Tensor::zeros(&[rn, e]);
            gemm_packed_into(xn.data(), &l.wq, q.data_mut(), rn);
            gemm_packed_into(xn.data(), &l.wk, k.data_mut(), rn);
            gemm_packed_into(xn.data(), &l.wv, v.data_mut(), rn);
            let mut qh = self.split_heads(q.data(), rn);
            let mut kh = self.split_heads(k.data(), rn);
            let vh = self.split_heads(v.data(), rn);
            if self.cfg.kind == ModelKind::Llama {
                for hh in 0..h {
                    for s in 0..rn {
                        let o = hh * rn * hd + s * hd;
                        ops::rope_inplace(&mut qh[o..o + hd], r0 + s, 10000.0);
                        ops::rope_inplace(&mut kh[o..o + hd], r0 + s, 10000.0);
                    }
                }
            }
            // stash the tail K/V into the cache pages
            for hh in 0..h {
                for s in 0..rn {
                    let src = hh * rn * hd + s * hd;
                    cache.write_pos(li, hh, r0 + s, &kh[src..src + hd], &vh[src..src + hd]);
                }
            }
            // gather the full (heads, seq, hd) K/V — shared prefix pages
            // plus the tail just written — for the offset attention
            let mut kf = scratch::take_uninit(h * seq * hd);
            let mut vf = scratch::take_uninit(h * seq * hd);
            for hh in 0..h {
                for pi in 0..n_pages {
                    let base = pi * page;
                    let rows = (seq - base).min(page);
                    let dst = hh * seq * hd + base * hd;
                    kf[dst..dst + rows * hd].copy_from_slice(&cache.k_head(li, hh, pi)[..rows * hd]);
                    vf[dst..dst + rows * hd].copy_from_slice(&cache.v_head(li, hh, pi)[..rows * hd]);
                }
            }
            let att = causal_attention_offset_thresh(&qh, &kf, &vf, h, rn, seq, hd, self.attn_th());
            let mut proj = Tensor::zeros(&[rn, e]);
            gemm_packed_into(&att, &l.wo, proj.data_mut(), rn);
            x.add_inplace(&proj);
            // MLP
            for s in 0..rn {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln2, nr);
            }
            let y = self.mlp(&xn, l);
            x.add_inplace(&y);
        }
        cache.len = seq;
        // final norm + head for the last position only
        let mut last = vec![0.0f32; e];
        self.norm(x.row(rn - 1), &self.w.final_norm, &mut last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm_packed_into(&last, &self.w.lm_head, &mut logits, 1);
        Ok(logits)
    }

    /// One decode step: append `token` at position `cache.len` and return
    /// the next-token logits. Grows the cache by a pool page when `pos`
    /// crosses a page boundary; pool exhaustion is a clean error before
    /// any cache state changes.
    pub fn decode(&self, token: u32, cache: &mut KvCache) -> Result<Vec<f32>> {
        let pos = cache.len;
        if pos >= self.cfg.max_seq {
            bail!("KV cache full ({} positions)", self.cfg.max_seq);
        }
        // decode's written page is structurally never a *shared* mapping
        // (only full prompt pages are ever shared, and `pos` lies past
        // them), so the writability pass is a cheap no-op check — it
        // exists to keep the write-path contract in one place
        cache.ensure_writable(pos + 1)?;
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        let mut x = self.w.tok_emb.row(token as usize).to_vec();
        if let Some(pe) = &self.w.pos_emb {
            for (a, &b) in x.iter_mut().zip(pe.row(pos)) {
                *a += b;
            }
        }
        let mut xn = vec![0.0f32; e];
        for (li, l) in self.w.layers.iter().enumerate() {
            self.norm(&x, &l.ln1, &mut xn);
            let mut q = vec![0.0f32; e];
            let mut k = vec![0.0f32; e];
            let mut v = vec![0.0f32; e];
            gemm_packed_into(&xn, &l.wq, &mut q, 1);
            gemm_packed_into(&xn, &l.wk, &mut k, 1);
            gemm_packed_into(&xn, &l.wv, &mut v, 1);
            if self.cfg.kind == ModelKind::Llama {
                for hh in 0..h {
                    ops::rope_inplace(&mut q[hh * hd..(hh + 1) * hd], pos, 10000.0);
                    ops::rope_inplace(&mut k[hh * hd..(hh + 1) * hd], pos, 10000.0);
                }
            }
            // write K/V at pos
            for hh in 0..h {
                cache.write_pos(li, hh, pos, &k[hh * hd..(hh + 1) * hd], &v[hh * hd..(hh + 1) * hd]);
            }
            // per-head paged attention fan-out (same kernel + item body as
            // decode_batch, so the two paths stay bit-identical)
            let mut att = vec![0.0f32; e];
            {
                let att_base = att.as_mut_ptr() as usize;
                let cache_ref: &KvCache = &*cache;
                let qd: &[f32] = &q;
                let page = self.kv_page();
                let th = self.attn_th();
                threadpool::parallel_for(h, |hh| {
                    // SAFETY: each head writes a disjoint `hd`-wide stripe
                    // of `att`; parallel_for blocks until all heads finish.
                    let orow = unsafe {
                        std::slice::from_raw_parts_mut((att_base as *mut f32).add(hh * hd), hd)
                    };
                    match th {
                        Some(at) => decode_head_paged_thresh_into(
                            &qd[hh * hd..(hh + 1) * hd],
                            hd,
                            page,
                            pos,
                            |pi| (cache_ref.k_head(li, hh, pi), cache_ref.v_head(li, hh, pi)),
                            |pi| cache_ref.k_stamp(li, hh, pi),
                            at,
                            orow,
                        ),
                        None => decode_head_paged_into(
                            &qd[hh * hd..(hh + 1) * hd],
                            hd,
                            page,
                            pos,
                            |pi| (cache_ref.k_head(li, hh, pi), cache_ref.v_head(li, hh, pi)),
                            orow,
                        ),
                    }
                });
            }
            let mut proj = vec![0.0f32; e];
            gemm_packed_into(&att, &l.wo, &mut proj, 1);
            for (a, b) in x.iter_mut().zip(&proj) {
                *a += b;
            }
            self.norm(&x, &l.ln2, &mut xn);
            let y = self.mlp(&Tensor::new(&[1, e], xn.clone()), l);
            for (a, &b) in x.iter_mut().zip(y.data()) {
                *a += b;
            }
        }
        cache.len = pos + 1;
        let mut last = vec![0.0f32; e];
        self.norm(&x, &self.w.final_norm, &mut last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm_packed_into(&last, &self.w.lm_head, &mut logits, 1);
        Ok(logits)
    }

    /// One batched decode step over `B` independent sessions: append
    /// `tokens[i]` at position `caches[i].len` and return the next-token
    /// logits of every session.
    ///
    /// The B hidden states are stacked into one `(B × d_model)` activation
    /// matrix so the QKV/output projections, the dense/sparse/fused MLP and
    /// the LM head each run as a **single** packed GEMM or BSpMM over the
    /// prepacked weights — every streamed weight panel / BCSC block is
    /// amortized over B rows instead of being re-read per session, which is
    /// what turns the decode round from latency-bound GEMV chains into a
    /// throughput-bound GEMM (the serving lever behind the paper's Fig. 6).
    /// Attention stays per-sequence over each session's KV cache,
    /// parallelized across `(session, head)` items on the thread pool.
    ///
    /// Outputs are bit-identical to calling [`Engine::decode`] once per
    /// session: the packed micro-kernel accumulates every output element
    /// serially over the depth dimension regardless of how many rows share
    /// the tile, and the per-head attention body is the exact code the
    /// sequential path runs.
    ///
    /// Validation is all-or-nothing over **token state**: if any session's
    /// cache is full, any token is out of vocab, or any session cannot get
    /// its next KV page from the pool, an error is returned before any K/V
    /// value is written or any `len` advanced, so the caller can retry
    /// with the offending session removed. Page *growth* is the one
    /// side effect an error may leave behind: sessions validated before
    /// the failing one keep the empty pages they acquired (they would need
    /// them for any retry, including the caller's sequential fallback).
    /// Ragged batches are the caller's concern — pass only the
    /// still-active sessions each round; `B = 0` is a no-op.
    ///
    /// # Panics
    /// If `tokens.len() != caches.len()`.
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(
            tokens.len(),
            caches.len(),
            "decode_batch: {} tokens vs {} caches",
            tokens.len(),
            caches.len()
        );
        let bsz = tokens.len();
        if bsz == 0 {
            return Ok(Vec::new());
        }
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        let max_seq = self.cfg.max_seq;
        // all-or-nothing validation before any token state is mutated
        for (i, (&t, c)) in tokens.iter().zip(caches.iter()).enumerate() {
            if c.len >= max_seq {
                bail!("decode_batch session {i}: KV cache full ({max_seq} positions)");
            }
            if t as usize >= self.cfg.vocab {
                bail!("decode_batch session {i}: token {t} out of vocab {}", self.cfg.vocab);
            }
        }
        // page growth up front: pool exhaustion surfaces as a clean error
        // before any K/V write or `len` bump (pages a session already
        // acquired stay with it for the caller's sequential fallback)
        for (i, c) in caches.iter_mut().enumerate() {
            c.ensure_writable(c.len + 1)
                .map_err(|e| e.context(format!("decode_batch session {i}")))?;
        }
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        // embed the B new tokens into one (B, e) activation matrix
        let mut x = Tensor::zeros(&[bsz, e]);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.w.tok_emb.row(t as usize));
            if let Some(pe) = &self.w.pos_emb {
                for (a, &b) in x.row_mut(i).iter_mut().zip(pe.row(positions[i])) {
                    *a += b;
                }
            }
        }
        let mut xn = Tensor::zeros(&[bsz, e]);
        // projection/attention activations come from the thread-local
        // scratch arena, so the per-layer hot loop recycles its buffers
        // after the first round (q/k/v/proj are re-zeroed per layer below;
        // att is fully overwritten by the attention fan-out)
        let mut q = scratch::take_uninit(bsz * e);
        let mut k = scratch::take_uninit(bsz * e);
        let mut v = scratch::take_uninit(bsz * e);
        let mut att = scratch::take_uninit(bsz * e);
        let mut proj = scratch::take_uninit(bsz * e);
        for (li, l) in self.w.layers.iter().enumerate() {
            // x and xn are distinct tensors, so the norm borrows directly —
            // no per-row copies on the batched hot path
            for i in 0..bsz {
                self.norm(x.row(i), &l.ln1, xn.row_mut(i));
            }
            // one batched GEMM per projection (gemm accumulates: zero first)
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            gemm_packed_into(xn.data(), &l.wq, &mut q, bsz);
            gemm_packed_into(xn.data(), &l.wk, &mut k, bsz);
            gemm_packed_into(xn.data(), &l.wv, &mut v, bsz);
            if self.cfg.kind == ModelKind::Llama {
                for i in 0..bsz {
                    let pos = positions[i];
                    for hh in 0..h {
                        let o = i * e + hh * hd;
                        ops::rope_inplace(&mut q[o..o + hd], pos, 10000.0);
                        ops::rope_inplace(&mut k[o..o + hd], pos, 10000.0);
                    }
                }
            }
            // write each session's K/V at its own position
            for (i, cache) in caches.iter_mut().enumerate() {
                let (kr, vr) = (&k[i * e..(i + 1) * e], &v[i * e..(i + 1) * e]);
                for hh in 0..h {
                    cache.write_pos(
                        li,
                        hh,
                        positions[i],
                        &kr[hh * hd..(hh + 1) * hd],
                        &vr[hh * hd..(hh + 1) * hd],
                    );
                }
            }
            // per-sequence paged attention, (session, head) items across
            // the pool, cost-weighted by position: a session at pos 500
            // walks ~8x the KV of one at pos 60, and uniform chunking
            // would let one long session serialize the round
            {
                let caches_ref: &[KvCache] = &*caches;
                let positions_ref: &[usize] = &positions;
                let qd: &[f32] = &q;
                let page = self.kv_page();
                let th = self.attn_th();
                let att_base = att.as_mut_ptr() as usize;
                threadpool::parallel_for_weighted(
                    bsz * h,
                    |t| positions_ref[t / h] + 1,
                    |t| {
                        let (i, hh) = (t / h, t % h);
                        let c = &caches_ref[i];
                        // SAFETY: each (session, head) item owns the
                        // disjoint span att[i, hh*hd..(hh+1)*hd]; the pool
                        // call blocks until all items finish.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(
                                (att_base as *mut f32).add(i * e + hh * hd),
                                hd,
                            )
                        };
                        match th {
                            Some(at) => decode_head_paged_thresh_into(
                                &qd[i * e + hh * hd..i * e + (hh + 1) * hd],
                                hd,
                                page,
                                positions_ref[i],
                                |pi| (c.k_head(li, hh, pi), c.v_head(li, hh, pi)),
                                |pi| c.k_stamp(li, hh, pi),
                                at,
                                orow,
                            ),
                            None => decode_head_paged_into(
                                &qd[i * e + hh * hd..i * e + (hh + 1) * hd],
                                hd,
                                page,
                                positions_ref[i],
                                |pi| (c.k_head(li, hh, pi), c.v_head(li, hh, pi)),
                                orow,
                            ),
                        }
                    },
                );
            }
            proj.fill(0.0);
            gemm_packed_into(&att, &l.wo, &mut proj, bsz);
            for (a, &b) in x.data_mut().iter_mut().zip(proj.iter()) {
                *a += b;
            }
            for i in 0..bsz {
                self.norm(x.row(i), &l.ln2, xn.row_mut(i));
            }
            let y = self.mlp(&xn, l);
            x.add_inplace(&y);
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        // final norm + one batched LM-head GEMM (both scratch-backed)
        let mut last = scratch::take_uninit(bsz * e);
        for i in 0..bsz {
            self.norm(x.row(i), &self.w.final_norm, &mut last[i * e..(i + 1) * e]);
        }
        let vocab = self.cfg.vocab;
        let mut logits = scratch::take_zeroed(bsz * vocab);
        gemm_packed_into(&last, &self.w.lm_head, &mut logits, bsz);
        Ok(logits.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        for i in 1..logits.len() {
            if logits[i] > logits[best] {
                best = i;
            }
        }
        best as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg(kind: ModelKind) -> NativeConfig {
        NativeConfig {
            name: "t".into(),
            kind,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 2,
            heads: 2,
            max_seq: 16,
            block: 8,
        }
    }

    fn test_params(cfg: &NativeConfig, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        if cfg.kind == ModelKind::Gpt2 {
            s.insert("pos_emb".into(), Tensor::randn(&[cfg.max_seq, e], 0.1, &mut rng));
        }
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        s
    }

    fn random_masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
        let mut rng = Rng::new(seed);
        let mut m = BTreeMap::new();
        for i in 0..cfg.layers {
            for (n, r, c) in cfg.mlp_shapes() {
                m.insert(
                    format!("layer{i}.{n}"),
                    BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
                );
            }
        }
        m
    }

    #[test]
    fn decode_matches_prefill_both_kinds() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            let cfg = test_cfg(kind);
            let params = test_params(&cfg, 1);
            let masks = random_masks(&cfg, 0.3, 2);
            let eng = Engine::new(cfg.clone(), &params, &masks, MlpMode::Dense).unwrap();
            let tokens: Vec<u32> = vec![3, 7, 11, 2, 9];
            // full prefill
            let mut c1 = eng.new_cache();
            let full = eng.prefill(&tokens, &mut c1).unwrap();
            // prefill on the prefix, then decode the last token
            let mut c2 = eng.new_cache();
            eng.prefill(&tokens[..4], &mut c2).unwrap();
            let step = eng.decode(tokens[4], &mut c2).unwrap();
            for (a, b) in full.iter().zip(&step) {
                assert!((a - b).abs() < 1e-3, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_and_dense_modes_agree() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            let cfg = test_cfg(kind);
            let params = test_params(&cfg, 3);
            let masks = random_masks(&cfg, 0.5, 4);
            let dense = Engine::new(cfg.clone(), &params, &masks, MlpMode::Dense).unwrap();
            let sparse = Engine::new(cfg.clone(), &params, &masks, MlpMode::Sparse).unwrap();
            let tokens: Vec<u32> = vec![1, 5, 9];
            let mut cd = dense.new_cache();
            let mut cs = sparse.new_cache();
            let ld = dense.prefill(&tokens, &mut cd).unwrap();
            let ls = sparse.prefill(&tokens, &mut cs).unwrap();
            for (a, b) in ld.iter().zip(&ls) {
                assert!((a - b).abs() < 1e-3, "{kind:?} prefill: {a} vs {b}");
            }
            let dd = dense.decode(2, &mut cd).unwrap();
            let ds = sparse.decode(2, &mut cs).unwrap();
            for (a, b) in dd.iter().zip(&ds) {
                assert!((a - b).abs() < 1e-3, "{kind:?} decode: {a} vs {b}");
            }
        }
    }

    /// `fork_with_fresh_kv` shares the prepacked weights (same `Arc`, no
    /// re-pack) but gives the fork its own empty pool with the original
    /// geometry/capacity/prefix setting — and the forked engine's streams
    /// are bit-identical to the original's.
    #[test]
    fn forked_engine_shares_weights_but_not_kv() {
        use crate::model::kv::KvOptions;
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 9);
        let masks = random_masks(&cfg, 0.4, 10);
        let eng = Engine::new_with_kv(
            cfg.clone(),
            &params,
            &masks,
            MlpMode::Sparse,
            KvOptions { page: 4, pool_pages: Some(16), prefix_cache: true },
        )
        .unwrap();
        let fork = eng.fork_with_fresh_kv();
        assert!(Arc::ptr_eq(&eng.w, &fork.w), "weights must be shared, not copied");
        assert!(!Arc::ptr_eq(&eng.kv_pool, &fork.kv_pool), "pools must be distinct");
        assert_eq!(fork.kv_pool.geom(), eng.kv_pool.geom());
        assert_eq!(fork.kv_pool.capacity_pages(), Some(16));
        assert!(fork.kv_pool.prefix_enabled());
        let tokens: Vec<u32> = vec![3, 1, 4, 1, 5];
        let mut ca = eng.new_cache();
        let mut cb = fork.new_cache();
        let la = eng.prefill(&tokens, &mut ca).unwrap();
        let lb = fork.prefill(&tokens, &mut cb).unwrap();
        assert_eq!(la, lb, "forked engine must serve bit-identical logits");
        // the original's pages live in its own pool only
        assert!(eng.kv_pool.pages_in_use() > 0);
        drop(cb);
        assert_eq!(fork.kv_pool.pages_in_use(), 0, "fork pool drains independently");
    }

    #[test]
    fn sparse_mode_shrinks_mlp_bytes() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 5);
        let dense_masks = BTreeMap::new();
        let sparse_masks = random_masks(&cfg, 0.75, 6);
        let dense = Engine::new(cfg.clone(), &params, &dense_masks, MlpMode::Sparse).unwrap();
        let sparse = Engine::new(cfg.clone(), &params, &sparse_masks, MlpMode::Sparse).unwrap();
        assert!(sparse.mlp_weight_bytes() < dense.mlp_weight_bytes() / 2);
    }

    /// The tentpole guarantee: batched decode is **bit-identical** to
    /// sequential decode — same logits bit patterns, same greedy streams —
    /// across ragged batch sizes (sessions finishing mid-round), both model
    /// kinds and both MLP modes.
    #[test]
    fn decode_batch_bitwise_matches_sequential_ragged() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            for mode in [MlpMode::Dense, MlpMode::Sparse] {
                let cfg = test_cfg(kind);
                let params = test_params(&cfg, 11);
                let masks = random_masks(&cfg, 0.5, 12);
                let eng = Engine::new(cfg.clone(), &params, &masks, mode).unwrap();
                let prompts: Vec<Vec<u32>> = vec![vec![3, 7, 11], vec![2], vec![9, 4, 1, 5]];
                // per-session decode budgets force sessions to retire
                // mid-round: batch shrinks 3 -> 2 -> 1
                let budgets = [6usize, 2, 4];
                // sequential greedy reference
                let mut seq_streams: Vec<Vec<u32>> = Vec::new();
                let mut seq_logits: Vec<Vec<f32>> = Vec::new();
                for (p, &n) in prompts.iter().zip(&budgets) {
                    let mut cache = eng.new_cache();
                    let logits = eng.prefill(p, &mut cache).unwrap();
                    let mut tok = Engine::argmax(&logits);
                    let mut stream = vec![tok];
                    let mut last = Vec::new();
                    for _ in 0..n {
                        last = eng.decode(tok, &mut cache).unwrap();
                        tok = Engine::argmax(&last);
                        stream.push(tok);
                    }
                    seq_streams.push(stream);
                    seq_logits.push(last);
                }
                // batched greedy over the shrinking active set
                let mut caches: Vec<KvCache> = Vec::new();
                let mut streams: Vec<Vec<u32>> = Vec::new();
                for p in &prompts {
                    let mut cache = eng.new_cache();
                    let logits = eng.prefill(p, &mut cache).unwrap();
                    streams.push(vec![Engine::argmax(&logits)]);
                    caches.push(cache);
                }
                let mut slots: Vec<Option<KvCache>> = caches.into_iter().map(Some).collect();
                let mut last_logits: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
                loop {
                    let live: Vec<usize> = (0..prompts.len())
                        .filter(|&i| streams[i].len() <= budgets[i])
                        .collect();
                    if live.is_empty() {
                        break;
                    }
                    let toks: Vec<u32> = live.iter().map(|&i| *streams[i].last().unwrap()).collect();
                    let mut round: Vec<KvCache> =
                        live.iter().map(|&i| slots[i].take().unwrap()).collect();
                    let logits = eng.decode_batch(&toks, &mut round).unwrap();
                    for ((&i, cache), l) in live.iter().zip(round).zip(logits) {
                        streams[i].push(Engine::argmax(&l));
                        last_logits[i] = l;
                        slots[i] = Some(cache);
                    }
                }
                for i in 0..prompts.len() {
                    assert_eq!(
                        streams[i], seq_streams[i],
                        "{kind:?}/{mode:?} session {i}: greedy streams diverged"
                    );
                    // bit-identical, not approximately equal
                    let same_bits = last_logits[i]
                        .iter()
                        .zip(&seq_logits[i])
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same_bits, "{kind:?}/{mode:?} session {i}: logits bits differ");
                }
            }
        }
    }

    #[test]
    fn decode_batch_single_session_equals_decode() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 21);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut c1 = eng.new_cache();
        let mut c2 = eng.new_cache();
        eng.prefill(&[5, 9], &mut c1).unwrap();
        eng.prefill(&[5, 9], &mut c2).unwrap();
        let a = eng.decode(3, &mut c1).unwrap();
        let b = eng.decode_batch(&[3], std::slice::from_mut(&mut c2)).unwrap();
        assert!(a.iter().zip(&b[0]).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(c1.len, c2.len);
    }

    #[test]
    fn decode_batch_empty_is_noop() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 22);
        let eng = Engine::new(cfg, &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        assert!(eng.decode_batch(&[], &mut []).unwrap().is_empty());
    }

    #[test]
    #[should_panic(expected = "decode_batch: 2 tokens vs 1 caches")]
    fn decode_batch_panics_on_shape_mismatch() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 23);
        let eng = Engine::new(cfg, &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut cache = eng.new_cache();
        eng.prefill(&[1], &mut cache).unwrap();
        let _ = eng.decode_batch(&[1, 2], std::slice::from_mut(&mut cache));
    }

    #[test]
    fn decode_batch_validates_before_mutating() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 24);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        // session 0 healthy, session 1 with a full cache
        let mut a = eng.new_cache();
        eng.prefill(&[1, 2], &mut a).unwrap();
        let mut b = eng.new_cache();
        eng.prefill(&vec![1; cfg.max_seq], &mut b).unwrap();
        let mut caches = vec![a, b];
        assert!(eng.decode_batch(&[1, 1], &mut caches).is_err());
        // all-or-nothing: the healthy session's cache must be untouched
        assert_eq!(caches[0].len, 2);
        assert_eq!(caches[1].len, cfg.max_seq);
        // out-of-vocab token also rejected upfront
        let err = eng.decode_batch(&[999], &mut caches[..1]).unwrap_err();
        assert!(err.to_string().contains("out of vocab"), "{err}");
        assert_eq!(caches[0].len, 2);
    }

    /// The tentpole layout guarantee end-to-end: a paged cache (page 4)
    /// and a "flat" cache (page = max_seq) produce **bit-identical**
    /// logits through prefill and decode, at prompt lengths page−1, page,
    /// page+1 and across decode steps that straddle page boundaries.
    #[test]
    fn paged_and_flat_layouts_bitwise_identical() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            let cfg = test_cfg(kind); // max_seq 16
            let params = test_params(&cfg, 31);
            let masks = random_masks(&cfg, 0.5, 32);
            let flat = Engine::new_with_kv(
                cfg.clone(),
                &params,
                &masks,
                MlpMode::Sparse,
                KvOptions { page: cfg.max_seq, pool_pages: None, prefix_cache: true },
            )
            .unwrap();
            let paged = Engine::new_with_kv(
                cfg.clone(),
                &params,
                &masks,
                MlpMode::Sparse,
                KvOptions { page: 4, pool_pages: None, prefix_cache: true },
            )
            .unwrap();
            for plen in [3usize, 4, 5] {
                let prompt: Vec<u32> = (0..plen).map(|i| (i as u32 * 5 + 1) % 32).collect();
                let mut cf = flat.new_cache();
                let mut cp = paged.new_cache();
                let lf = flat.prefill(&prompt, &mut cf).unwrap();
                let lp = paged.prefill(&prompt, &mut cp).unwrap();
                assert!(
                    lf.iter().zip(&lp).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{kind:?} plen={plen}: prefill logits bits differ"
                );
                // greedy decode across the next page boundary (positions
                // plen..plen+6 cross page 1 → 2 for every plen here)
                let mut tok = Engine::argmax(&lf);
                for step in 0..6 {
                    let a = flat.decode(tok, &mut cf).unwrap();
                    let b = paged.decode(tok, &mut cp).unwrap();
                    assert!(
                        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{kind:?} plen={plen} step={step}: decode logits bits differ"
                    );
                    tok = Engine::argmax(&a);
                }
                assert_eq!(cf.len, cp.len);
            }
        }
    }

    /// Ragged batches straddling page boundaries: decode_batch over paged
    /// caches is bitwise equal to decode_batch over flat caches, with
    /// per-session lengths page−1 / page / page+1 diverging as they grow.
    #[test]
    fn decode_batch_paged_matches_flat_across_page_straddle() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 33);
        let masks = random_masks(&cfg, 0.5, 34);
        let mk = |page: usize| {
            Engine::new_with_kv(
                cfg.clone(),
                &params,
                &masks,
                MlpMode::Dense,
                KvOptions { page, pool_pages: None, prefix_cache: true },
            )
            .unwrap()
        };
        let flat = mk(cfg.max_seq);
        let paged = mk(4);
        let prompts: Vec<Vec<u32>> = vec![
            (0..3).map(|i| i as u32 + 2).collect(), // page − 1
            (0..4).map(|i| i as u32 * 3 + 1).collect(), // page
            (0..5).map(|i| i as u32 * 2 + 7).collect(), // page + 1
        ];
        let (mut cf, mut cp, mut toks) = (Vec::new(), Vec::new(), Vec::new());
        for p in &prompts {
            let mut a = flat.new_cache();
            let mut b = paged.new_cache();
            let la = flat.prefill(p, &mut a).unwrap();
            let lb = paged.prefill(p, &mut b).unwrap();
            assert_eq!(Engine::argmax(&la), Engine::argmax(&lb));
            toks.push(Engine::argmax(&la));
            cf.push(a);
            cp.push(b);
        }
        // 8 rounds walk every session across at least two page boundaries
        for round in 0..8 {
            let la = flat.decode_batch(&toks, &mut cf).unwrap();
            let lb = paged.decode_batch(&toks, &mut cp).unwrap();
            for (i, (a, b)) in la.iter().zip(&lb).enumerate() {
                assert!(
                    a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "round {round} session {i}: logits bits differ paged vs flat"
                );
            }
            toks = la.iter().map(|l| Engine::argmax(l)).collect();
        }
        for (a, b) in cf.iter().zip(&cp) {
            assert_eq!(a.len, b.len);
            // paged residency never exceeds the flat bound
            assert!(b.bytes() <= a.bytes());
        }
    }

    /// Pool exhaustion is a clean error through prefill, decode and
    /// decode_batch — never a panic — and leaves token state untouched.
    #[test]
    fn pool_exhaustion_clean_errors() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 35);
        let eng = Engine::new_with_kv(
            cfg.clone(),
            &params,
            &BTreeMap::new(),
            MlpMode::Dense,
            KvOptions { page: 4, pool_pages: Some(2), prefix_cache: true }, // 8 positions total
        )
        .unwrap();
        // prefill needing 3 pages fails cleanly, len untouched
        let mut c = eng.new_cache();
        let err = eng.prefill(&vec![1u32; 9], &mut c).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        assert_eq!(c.len, 0);
        // the pages it did acquire stay usable: an 8-token prefill fits
        eng.prefill(&vec![1u32; 8], &mut c).unwrap();
        assert_eq!(c.len, 8);
        // decode would need page 3 of 2 → clean error, len unchanged
        let err = eng.decode(1, &mut c).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        assert_eq!(c.len, 8);
        // decode_batch surfaces the same error with the session index and
        // without touching any session's len
        let mut caches = vec![c];
        let err = eng.decode_batch(&[1], &mut caches).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("session 0") && msg.contains("exhausted"), "{msg}");
        assert_eq!(caches[0].len, 8);
    }

    /// `KvCache::bytes` reports resident pages, not the max_seq bound.
    #[test]
    fn cache_bytes_report_resident_pages() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 36);
        let eng = Engine::new_with_kv(
            cfg.clone(),
            &params,
            &BTreeMap::new(),
            MlpMode::Dense,
            KvOptions { page: 4, pool_pages: None, prefix_cache: true },
        )
        .unwrap();
        let page_bytes = eng.kv_pool().geom().page_bytes();
        let mut c = eng.new_cache();
        assert_eq!(c.bytes(), 0);
        eng.prefill(&[1, 2, 3, 4, 5], &mut c).unwrap(); // 5 positions → 2 pages
        assert_eq!(c.bytes(), 2 * page_bytes);
        assert!(c.bytes() < eng.flat_kv_bytes());
        // flat bound matches the seed preallocation formula
        assert_eq!(
            eng.flat_kv_bytes(),
            2 * cfg.layers * cfg.heads * cfg.max_seq * cfg.head_dim() * 4
        );
        // pool accounting follows the live cache
        assert_eq!(eng.kv_pool().pages_in_use(), 2);
        drop(c);
        assert_eq!(eng.kv_pool().pages_in_use(), 0);
        assert_eq!(eng.kv_pool().high_water_pages(), 2);
    }

    #[test]
    fn zero_page_size_rejected() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 37);
        assert!(Engine::new_with_kv(
            cfg,
            &params,
            &BTreeMap::new(),
            MlpMode::Dense,
            KvOptions { page: 0, pool_pages: None, prefix_cache: true },
        )
        .is_err());
    }

    /// The prefix-sharing acceptance gate: N sessions sharing a prefix
    /// through the prefix cache produce **bit-identical** logits — at
    /// prefill and through ragged decode batches — to N independent
    /// sessions replaying the prefix on a sharing-disabled engine, at
    /// prefix lengths page−1 / page / page+1 (page 4). The empty tail
    /// exercises the full-hit path (last position recomputed into a CoW
    /// page).
    #[test]
    fn shared_prefix_bitwise_matches_independent_replay() {
        for mode in [MlpMode::Dense, MlpMode::Sparse] {
            let cfg = test_cfg(ModelKind::Llama); // max_seq 16
            let params = test_params(&cfg, 41);
            let masks = random_masks(&cfg, 0.5, 42);
            let mk = |prefix_cache: bool| {
                Engine::new_with_kv(
                    cfg.clone(),
                    &params,
                    &masks,
                    mode,
                    KvOptions { page: 4, pool_pages: None, prefix_cache },
                )
                .unwrap()
            };
            let shared = mk(true);
            let plain = mk(false);
            for pfx_len in [3usize, 4, 5] {
                let prefix: Vec<u32> = (0..pfx_len).map(|i| (i as u32 * 3 + 2) % 32).collect();
                // empty tail = prompt == prefix (full hit for followers)
                let tails: Vec<Vec<u32>> = vec![vec![9, 1], vec![], vec![25, 30, 4], vec![17]];
                let prompts: Vec<Vec<u32>> = tails
                    .iter()
                    .map(|t| prefix.iter().chain(t).copied().collect())
                    .collect();
                let stats0 = shared.kv_pool().prefix_stats();
                // shared engine: sessions prefilled in order, kept alive
                // together so followers map the donor's pages
                let mut sc: Vec<KvCache> = Vec::new();
                let mut sl: Vec<Vec<f32>> = Vec::new();
                for p in &prompts {
                    let mut c = shared.new_cache();
                    sl.push(shared.prefill(p, &mut c).unwrap());
                    sc.push(c);
                }
                // plain engine: every session replays its full prompt
                let mut pc: Vec<KvCache> = Vec::new();
                for (i, p) in prompts.iter().enumerate() {
                    let mut c = plain.new_cache();
                    let l = plain.prefill(p, &mut c).unwrap();
                    assert!(
                        l.iter().zip(&sl[i]).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{mode:?} pfx={pfx_len} session {i}: prefill logits bits differ"
                    );
                    pc.push(c);
                }
                // sharing must actually engage once the prefix fills a page:
                // session 1's prompt is exactly the prefix, so with
                // pfx_len == 4 every follower hits and the full-hit
                // session copy-on-writes
                let stats = shared.kv_pool().prefix_stats();
                if pfx_len >= 4 {
                    assert!(
                        stats.pages_shared > stats0.pages_shared,
                        "{mode:?} pfx={pfx_len}: no pages were shared"
                    );
                    assert!(
                        stats.cow_copies > stats0.cow_copies,
                        "{mode:?} pfx={pfx_len}: the full hit never copy-on-wrote"
                    );
                }
                // ragged decode: session i retires after i+2 steps, so the
                // batch shrinks while page boundaries are straddled
                let mut toks: Vec<u32> = sl.iter().map(|l| Engine::argmax(l)).collect();
                let mut ptoks = toks.clone();
                for round in 0..5 {
                    let live: Vec<usize> =
                        (0..prompts.len()).filter(|&i| round < i + 2).collect();
                    if live.is_empty() {
                        break;
                    }
                    let lt: Vec<u32> = live.iter().map(|&i| toks[i]).collect();
                    let mut lc: Vec<KvCache> = Vec::new();
                    for &i in live.iter().rev() {
                        lc.insert(0, sc.remove(i));
                    }
                    let sout = shared.decode_batch(&lt, &mut lc).unwrap();
                    for (j, &i) in live.iter().enumerate() {
                        // plain side decodes sequentially (its batched and
                        // sequential paths are already proven bit-equal)
                        let pout = plain.decode(ptoks[i], &mut pc[i]).unwrap();
                        assert!(
                            sout[j].iter().zip(&pout).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "{mode:?} pfx={pfx_len} round {round} session {i}: decode bits differ"
                        );
                        toks[i] = Engine::argmax(&sout[j]);
                        ptoks[i] = Engine::argmax(&pout);
                    }
                    for (&i, c) in live.iter().zip(lc) {
                        sc.insert(i, c);
                    }
                }
                drop(sc);
                drop(pc);
                assert_eq!(shared.kv_pool().pages_in_use(), 0);
                assert_eq!(shared.kv_pool().logical_pages(), 0);
            }
        }
    }

    #[test]
    fn cache_overflow_and_bad_token_rejected() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 7);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut c = eng.new_cache();
        assert!(eng.prefill(&[999], &mut c).is_err());
        let long: Vec<u32> = vec![1; cfg.max_seq + 1];
        assert!(eng.prefill(&long, &mut c).is_err());
        eng.prefill(&vec![1; cfg.max_seq], &mut c).unwrap();
        assert!(eng.decode(1, &mut c).is_err());
    }

    /// Drive an exact engine and a candidate engine through the same
    /// serving matrix — plain prefill (page−1/page/page+1 prompts),
    /// prefix-resume prefill, decode across page boundaries, and a
    /// ragged `decode_batch` — asserting bit-identical logits
    /// throughout. Shared by the τ=off and huge-τ identity tests.
    fn assert_engines_bitwise_identical(exact: &Engine, cand: &Engine, tag: &str) {
        // plain prefill + decode at prompt lengths page−1/page/page+1
        for plen in [3usize, 4, 5] {
            let prompt: Vec<u32> = (0..plen).map(|i| (i as u32 * 5 + 1) % 32).collect();
            let mut ce = exact.new_cache();
            let mut cc = cand.new_cache();
            let le = exact.prefill(&prompt, &mut ce).unwrap();
            let lc = cand.prefill(&prompt, &mut cc).unwrap();
            assert!(
                le.iter().zip(&lc).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{tag} plen={plen}: prefill logits bits differ"
            );
            let mut tok = Engine::argmax(&le);
            for step in 0..6 {
                let a = exact.decode(tok, &mut ce).unwrap();
                let b = cand.decode(tok, &mut cc).unwrap();
                assert!(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{tag} plen={plen} step={step}: decode logits bits differ"
                );
                tok = Engine::argmax(&a);
            }
        }
        // prefix-resume: a second session re-prefilling prefix+tail hits
        // the prefix cache and runs the offset kernel on the tail
        let prefix: Vec<u32> = (0..5).map(|i| (i as u32 * 3 + 2) % 32).collect();
        let mut warm_e = exact.new_cache();
        let mut warm_c = cand.new_cache();
        exact.prefill(&prefix, &mut warm_e).unwrap();
        cand.prefill(&prefix, &mut warm_c).unwrap();
        let mut full = prefix.clone();
        full.extend_from_slice(&[7, 11, 13]);
        let mut re = exact.new_cache();
        let mut rc = cand.new_cache();
        let le = exact.prefill(&full, &mut re).unwrap();
        let lc = cand.prefill(&full, &mut rc).unwrap();
        assert!(
            le.iter().zip(&lc).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{tag}: prefix-resume logits bits differ"
        );
        // ragged decode_batch over sessions of uneven length
        let prompts: Vec<Vec<u32>> = vec![vec![3, 7, 11], vec![2], vec![9, 4, 1, 5]];
        let (mut ce, mut cc, mut te, mut tc) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for p in &prompts {
            let mut a = exact.new_cache();
            let mut b = cand.new_cache();
            let la = exact.prefill(p, &mut a).unwrap();
            let lb = cand.prefill(p, &mut b).unwrap();
            te.push(Engine::argmax(&la));
            tc.push(Engine::argmax(&lb));
            ce.push(a);
            cc.push(b);
        }
        for round in 0..6 {
            let la = exact.decode_batch(&te, &mut ce).unwrap();
            let lb = cand.decode_batch(&tc, &mut cc).unwrap();
            for (i, (a, b)) in la.iter().zip(&lb).enumerate() {
                assert!(
                    a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{tag} round {round} session {i}: decode_batch bits differ"
                );
            }
            te = la.iter().map(|l| Engine::argmax(l)).collect();
            tc = lb.iter().map(|l| Engine::argmax(l)).collect();
        }
    }

    /// τ=off acceptance gate: `AttnOptions::default()` through
    /// `new_with_opts` is bit-identical to the plain `new_with_kv`
    /// engine on every serving path, and the counters never move.
    #[test]
    fn attn_threshold_off_is_bitwise_identical() {
        for mode in [MlpMode::Dense, MlpMode::Sparse] {
            let cfg = test_cfg(ModelKind::Llama); // max_seq 16
            let params = test_params(&cfg, 51);
            let masks = random_masks(&cfg, 0.5, 52);
            let kv = KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true };
            let exact =
                Engine::new_with_kv(cfg.clone(), &params, &masks, mode, kv.clone()).unwrap();
            let off = Engine::new_with_opts(
                cfg.clone(),
                &params,
                &masks,
                mode,
                kv,
                AttnOptions::default(),
            )
            .unwrap();
            assert_eq!(off.attn_threshold(), None);
            assert!(!off.kv_pool().stamping_enabled());
            assert_engines_bitwise_identical(&exact, &off, &format!("{mode:?}/tau=off"));
            // exact paths never touch the counters
            assert_eq!(off.attn_stats(), AttnStats::default());
            assert!(!off.attn_stats().engaged());
        }
    }

    /// A huge τ arms every threshold code path — stamped pool, thresh
    /// prefill/offset/decode kernels — yet skips nothing, so streams
    /// stay bit-identical to exact attention while the visit counters
    /// prove the armed paths actually ran.
    #[test]
    fn attn_threshold_huge_tau_bitwise_and_counts_visits() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 53);
        let masks = random_masks(&cfg, 0.5, 54);
        let kv = KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true };
        let exact = Engine::new_with_kv(cfg.clone(), &params, &masks, MlpMode::Dense, kv.clone())
            .unwrap();
        let armed = Engine::new_with_opts(
            cfg.clone(),
            &params,
            &masks,
            MlpMode::Dense,
            kv,
            AttnOptions { threshold: Some(1e30) },
        )
        .unwrap();
        assert!(armed.kv_pool().stamping_enabled());
        assert_engines_bitwise_identical(&exact, &armed, "tau=1e30");
        let st = armed.attn_stats();
        assert!(st.engaged(), "armed engine should have visited tiles/pages");
        assert!(st.rows > 0 && st.tiles > 0 && st.pages > 0, "{st:?}");
        assert_eq!(st.rows_skipped, 0, "{st:?}");
        assert_eq!(st.tiles_skipped, 0, "{st:?}");
        assert_eq!(st.pages_skipped, 0, "{st:?}");
        // fork keeps the options (and stamping) but starts fresh counters
        let fork = armed.fork_with_fresh_kv();
        assert_eq!(fork.attn_options(), armed.attn_options());
        assert!(fork.kv_pool().stamping_enabled());
        assert_eq!(fork.attn_stats(), AttnStats::default());
    }

    /// A finite τ on a real engine skips work while keeping logits
    /// close to exact, and drift/skips are monotone in τ.
    #[test]
    fn attn_threshold_engine_drift_and_skips_monotone() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 55);
        let masks = random_masks(&cfg, 0.5, 56);
        let kv = KvOptions { page: 4, pool_pages: Some(64), prefix_cache: false };
        let exact = Engine::new_with_kv(cfg.clone(), &params, &masks, MlpMode::Dense, kv.clone())
            .unwrap();
        let prompt: Vec<u32> = (0..12).map(|i| (i as u32 * 7 + 3) % 32).collect();
        let mut ce = exact.new_cache();
        let le = exact.prefill(&prompt, &mut ce).unwrap();
        let mut prev_skipped = u64::MAX;
        let mut prev_drift = f32::INFINITY;
        for tau in [0.5f32, 4.0, 1e30] {
            let eng = Engine::new_with_opts(
                cfg.clone(),
                &params,
                &masks,
                MlpMode::Dense,
                kv.clone(),
                AttnOptions { threshold: Some(tau) },
            )
            .unwrap();
            let mut c = eng.new_cache();
            let l = eng.prefill(&prompt, &mut c).unwrap();
            let drift = l
                .iter()
                .zip(&le)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let st = eng.attn_stats();
            assert!(st.rows > 0, "tau={tau}: counters never engaged");
            assert!(
                st.rows_skipped <= prev_skipped,
                "tau={tau}: skips grew as tau grew ({} > {prev_skipped})",
                st.rows_skipped
            );
            assert!(
                drift <= prev_drift + 1e-6,
                "tau={tau}: drift grew as tau grew ({drift} > {prev_drift})"
            );
            prev_skipped = st.rows_skipped;
            prev_drift = drift;
        }
        assert_eq!(prev_drift, 0.0, "tau=1e30 must be exact");
        assert_eq!(prev_skipped, 0, "tau=1e30 must skip nothing");
    }

    /// NaN / negative / infinite τ are rejected at engine build with a
    /// clean error — never a silently-garbage skip mask.
    #[test]
    fn attn_threshold_rejects_nan_negative_inf() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 57);
        for bad in [f32::NAN, -1.0, -0.5, f32::INFINITY, f32::NEG_INFINITY] {
            let err = Engine::new_with_opts(
                cfg.clone(),
                &params,
                &BTreeMap::new(),
                MlpMode::Dense,
                KvOptions { page: 4, pool_pages: None, prefix_cache: true },
                AttnOptions { threshold: Some(bad) },
            )
            .unwrap_err();
            assert!(
                err.to_string().contains("attention threshold"),
                "tau={bad}: wrong error: {err}"
            );
        }
        // τ = 0.0 is aggressive but legal
        assert!(Engine::new_with_opts(
            cfg.clone(),
            &params,
            &BTreeMap::new(),
            MlpMode::Dense,
            KvOptions { page: 4, pool_pages: None, prefix_cache: true },
            AttnOptions { threshold: Some(0.0) },
        )
        .is_ok());
    }
}
