//! Native block-sparse inference engine.
//!
//! Runs the same Transformer the L2 JAX model defines, but entirely on the
//! native kernel stack, with the MLP weights in either dense (GEMM) or
//! BCSC (BSpMM) form. This is the component behind the paper's Fig. 6:
//! identical weights + masks, two execution modes, and the wall-clock gap
//! between them is the end-to-end inference speedup of block sparsity.
//!
//! Sessions are per-sequence (each owns a [`KvCache`]) over shared weights.
//! The serving coordinator multiplexes many sessions and drives each decode
//! round either one session at a time ([`Engine::decode`], a chain of
//! 1-row GEMVs) or — the throughput path — as one [`Engine::decode_batch`]
//! call that stacks the B active sessions' hidden states into a single
//! `(B × d_model)` activation matrix, so every projection, MLP and the LM
//! head run as one packed GEMM/BSpMM over the prepacked weights. Attention
//! stays per-sequence (each session has its own cache and position) and is
//! parallelized across `(session, head)` items on the thread pool. Both
//! paths share per-row arithmetic and summation order, so greedy decode
//! streams are **bit-identical** batched vs sequential.
//!
//! All dense weight matrices (attention projections, LM head, dense-mode
//! MLP weights) are packed into [`PackedB`] panel form **once at engine
//! build time**, so every prefill and decode projection runs the packed
//! micro-kernel without any per-call packing sweep; dense-MLP hidden
//! buffers come from the thread-local scratch arena instead of per-call
//! allocations.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::kernels::attention::{causal_attention, decode_attention, decode_head_into};
use crate::kernels::bspmm::{fused_mlp_sparse, gelu_mlp_sparse, FusedMlpWeights};
use crate::kernels::gemm::gemm_packed_into;
use crate::kernels::ops;
use crate::kernels::pack::PackedB;
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::params::ParamStore;
use crate::sparse::{Bcsc, BlockMask};
use crate::tensor::Tensor;
use crate::util::{scratch, threadpool};

/// MLP execution mode (the Fig. 6 switch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MlpMode {
    /// Masked weights stored dense, multiplied with the dense GEMM — the
    /// baseline (what a dense-only runtime would do).
    Dense,
    /// Masked weights stored in BCSC, multiplied with BSpMM + fused
    /// nonlinearity — the paper's kernel.
    Sparse,
}

enum MlpWeights {
    DenseSwiglu { w1: PackedB, w2: PackedB, w3: PackedB },
    DenseGelu { w1: PackedB, w3: PackedB },
    SparseSwiglu { w1: Bcsc, w2: Bcsc, w3: Bcsc },
    SparseGelu { w1: Bcsc, w3: Bcsc },
}

struct LayerWeights {
    ln1: Vec<f32>,
    wq: PackedB,
    wk: PackedB,
    wv: PackedB,
    wo: PackedB,
    ln2: Vec<f32>,
    mlp: MlpWeights,
}

/// Per-sequence KV cache: one `(heads * max_seq * hd)` buffer per layer.
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Number of valid positions.
    pub len: usize,
}

impl KvCache {
    /// Resident bytes of the cache (both K and V, all layers).
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.len() * 4).sum()
    }
}

/// The native block-sparse inference engine: embeddings, prepacked
/// projection/LM-head weights, and per-layer MLP weights in dense
/// ([`PackedB`]) or sparse ([`Bcsc`]) form depending on [`MlpMode`].
pub struct Engine {
    cfg: NativeConfig,
    mode: MlpMode,
    tok_emb: Tensor,
    pos_emb: Option<Tensor>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
    lm_head: PackedB,
}

/// Masked dense weight, packed once into micro-kernel panel form.
fn masked_packed(
    params: &ParamStore,
    masks: &BTreeMap<String, BlockMask>,
    name: &str,
    block: usize,
) -> PackedB {
    let mut t = params.req(name).clone();
    if let Some(m) = masks.get(name) {
        m.apply_to(t.data_mut(), block);
    }
    PackedB::pack(t.data(), t.rows(), t.cols())
}

/// Unmasked dense weight (projections), packed once.
fn packed(params: &ParamStore, name: &str) -> PackedB {
    let t = params.req(name);
    PackedB::pack(t.data(), t.rows(), t.cols())
}

fn bcsc_of(params: &ParamStore, masks: &BTreeMap<String, BlockMask>, name: &str, block: usize) -> Bcsc {
    let t = params.req(name);
    let full;
    let mask = match masks.get(name) {
        Some(m) => m,
        None => {
            full = BlockMask::ones(t.rows() / block, t.cols() / block);
            &full
        }
    };
    Bcsc::from_dense(t, mask, block)
}

impl Engine {
    /// Build an engine over trained parameters + masks.
    pub fn new(
        cfg: NativeConfig,
        params: &ParamStore,
        masks: &BTreeMap<String, BlockMask>,
        mode: MlpMode,
    ) -> Result<Engine> {
        if cfg.kind == ModelKind::Vit {
            bail!("the autoregressive engine serves LM configs; use the eval drivers for ViT");
        }
        let b = cfg.block;
        let mut layers = Vec::with_capacity(cfg.layers);
        for i in 0..cfg.layers {
            let p = |s: &str| format!("layer{i}.{s}");
            let mlp = match (cfg.kind, mode) {
                (ModelKind::Llama, MlpMode::Dense) => MlpWeights::DenseSwiglu {
                    w1: masked_packed(params, masks, &p("mlp.w1"), b),
                    w2: masked_packed(params, masks, &p("mlp.w2"), b),
                    w3: masked_packed(params, masks, &p("mlp.w3"), b),
                },
                (ModelKind::Llama, MlpMode::Sparse) => MlpWeights::SparseSwiglu {
                    w1: bcsc_of(params, masks, &p("mlp.w1"), b),
                    w2: bcsc_of(params, masks, &p("mlp.w2"), b),
                    w3: bcsc_of(params, masks, &p("mlp.w3"), b),
                },
                (_, MlpMode::Dense) => MlpWeights::DenseGelu {
                    w1: masked_packed(params, masks, &p("mlp.w1"), b),
                    w3: masked_packed(params, masks, &p("mlp.w3"), b),
                },
                (_, MlpMode::Sparse) => MlpWeights::SparseGelu {
                    w1: bcsc_of(params, masks, &p("mlp.w1"), b),
                    w3: bcsc_of(params, masks, &p("mlp.w3"), b),
                },
            };
            layers.push(LayerWeights {
                ln1: params.req(&p("ln1")).data().to_vec(),
                wq: packed(params, &p("attn.wq")),
                wk: packed(params, &p("attn.wk")),
                wv: packed(params, &p("attn.wv")),
                wo: packed(params, &p("attn.wo")),
                ln2: params.req(&p("ln2")).data().to_vec(),
                mlp,
            });
        }
        Ok(Engine {
            mode,
            tok_emb: params.req("tok_emb").clone(),
            pos_emb: params.get("pos_emb").cloned(),
            layers,
            final_norm: params.req("final_norm").data().to_vec(),
            lm_head: packed(params, "lm_head"),
            cfg,
        })
    }

    /// The geometry this engine was built for.
    pub fn config(&self) -> &NativeConfig {
        &self.cfg
    }

    /// Dense or sparse MLP execution (fixed at build time).
    pub fn mode(&self) -> MlpMode {
        self.mode
    }

    /// Weight bytes resident for the MLP blocks in the current mode — the
    /// per-model input to the Fig. 7 memory model.
    pub fn mlp_weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match &l.mlp {
                MlpWeights::DenseSwiglu { w1, w2, w3 } => w1.bytes() + w2.bytes() + w3.bytes(),
                MlpWeights::DenseGelu { w1, w3 } => w1.bytes() + w3.bytes(),
                MlpWeights::SparseSwiglu { w1, w2, w3 } => w1.bytes() + w2.bytes() + w3.bytes(),
                MlpWeights::SparseGelu { w1, w3 } => w1.bytes() + w3.bytes(),
            })
            .sum()
    }

    /// A zeroed KV cache sized for one `max_seq`-long session.
    pub fn new_cache(&self) -> KvCache {
        let per_layer = self.cfg.heads * self.cfg.max_seq * self.cfg.head_dim();
        KvCache {
            k: (0..self.cfg.layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..self.cfg.layers).map(|_| vec![0.0; per_layer]).collect(),
            len: 0,
        }
    }

    fn norm(&self, x: &[f32], g: &[f32], out: &mut [f32]) {
        match self.cfg.kind {
            ModelKind::Llama => ops::rmsnorm(x, g, out, 1e-5),
            _ => ops::layernorm(x, g, out, 1e-5),
        }
    }

    fn mlp(&self, x: &Tensor, l: &LayerWeights) -> Tensor {
        match &l.mlp {
            MlpWeights::SparseSwiglu { w1, w2, w3 } => {
                fused_mlp_sparse(x, &FusedMlpWeights { w1, w2, w3 })
            }
            MlpWeights::SparseGelu { w1, w3 } => gelu_mlp_sparse(x, w1, w3),
            MlpWeights::DenseSwiglu { w1, w2, w3 } => {
                let m = x.rows();
                let (e, f) = (w1.k, w1.n);
                // scratch-arena hidden tiles: no per-call allocation
                let mut h1 = scratch::take_zeroed(m * f);
                let mut h2 = scratch::take_zeroed(m * f);
                gemm_packed_into(x.data(), w1, &mut h1, m);
                gemm_packed_into(x.data(), w2, &mut h2, m);
                for (a, &bb) in h1.iter_mut().zip(h2.iter()) {
                    *a = ops::silu(*a) * bb;
                }
                let mut y = Tensor::zeros(&[m, e]);
                gemm_packed_into(&h1, w3, y.data_mut(), m);
                y
            }
            MlpWeights::DenseGelu { w1, w3 } => {
                let m = x.rows();
                let (e, f) = (w1.k, w1.n);
                let mut h = scratch::take_zeroed(m * f);
                gemm_packed_into(x.data(), w1, &mut h, m);
                for a in h.iter_mut() {
                    *a = ops::gelu(*a);
                }
                let mut y = Tensor::zeros(&[m, e]);
                gemm_packed_into(&h, w3, y.data_mut(), m);
                y
            }
        }
    }

    /// (seq, e) row-major → (heads, seq, hd) head-major.
    fn split_heads(&self, x: &[f32], seq: usize) -> Vec<f32> {
        let (h, hd, e) = (self.cfg.heads, self.cfg.head_dim(), self.cfg.emb);
        let mut out = vec![0.0f32; seq * e];
        for s in 0..seq {
            for hh in 0..h {
                out[hh * seq * hd + s * hd..hh * seq * hd + (s + 1) * hd]
                    .copy_from_slice(&x[s * e + hh * hd..s * e + (hh + 1) * hd]);
            }
        }
        out
    }

    /// Prompt pass: fills `cache` for positions `0..tokens.len()` and
    /// returns the logits of the last position.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> Result<Vec<f32>> {
        let seq = tokens.len();
        if seq == 0 || seq > self.cfg.max_seq {
            bail!("prompt length {seq} out of range 1..={}", self.cfg.max_seq);
        }
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        // embed
        let mut x = Tensor::zeros(&[seq, e]);
        for (s, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.cfg.vocab {
                bail!("token {t} out of vocab {}", self.cfg.vocab);
            }
            x.row_mut(s).copy_from_slice(self.tok_emb.row(t));
            if let Some(pe) = &self.pos_emb {
                for (a, &b) in x.row_mut(s).iter_mut().zip(pe.row(s)) {
                    *a += b;
                }
            }
        }

        let mut xn = Tensor::zeros(&[seq, e]);
        for (li, l) in self.layers.iter().enumerate() {
            // pre-norm
            for s in 0..seq {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln1, nr);
            }
            // projections
            let mut q = Tensor::zeros(&[seq, e]);
            let mut k = Tensor::zeros(&[seq, e]);
            let mut v = Tensor::zeros(&[seq, e]);
            gemm_packed_into(xn.data(), &l.wq, q.data_mut(), seq);
            gemm_packed_into(xn.data(), &l.wk, k.data_mut(), seq);
            gemm_packed_into(xn.data(), &l.wv, v.data_mut(), seq);
            let mut qh = self.split_heads(q.data(), seq);
            let mut kh = self.split_heads(k.data(), seq);
            let vh = self.split_heads(v.data(), seq);
            if self.cfg.kind == ModelKind::Llama {
                for hh in 0..h {
                    for s in 0..seq {
                        let o = hh * seq * hd + s * hd;
                        ops::rope_inplace(&mut qh[o..o + hd], s, 10000.0);
                        ops::rope_inplace(&mut kh[o..o + hd], s, 10000.0);
                    }
                }
            }
            // stash K/V into the cache (head-major, max_seq stride)
            for hh in 0..h {
                for s in 0..seq {
                    let src = hh * seq * hd + s * hd;
                    let dst = hh * self.cfg.max_seq * hd + s * hd;
                    cache.k[li][dst..dst + hd].copy_from_slice(&kh[src..src + hd]);
                    cache.v[li][dst..dst + hd].copy_from_slice(&vh[src..src + hd]);
                }
            }
            let att = causal_attention(&qh, &kh, &vh, h, seq, hd);
            let mut proj = Tensor::zeros(&[seq, e]);
            gemm_packed_into(&att, &l.wo, proj.data_mut(), seq);
            x.add_inplace(&proj);
            // MLP
            for s in 0..seq {
                let (xr, nr) = (x.row(s).to_vec(), xn.row_mut(s));
                self.norm(&xr, &l.ln2, nr);
            }
            let y = self.mlp(&xn, l);
            x.add_inplace(&y);
        }
        cache.len = seq;
        // final norm + head for the last position only
        let mut last = vec![0.0f32; e];
        self.norm(x.row(seq - 1), &self.final_norm, &mut last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm_packed_into(&last, &self.lm_head, &mut logits, 1);
        Ok(logits)
    }

    /// One decode step: append `token` at position `cache.len` and return
    /// the next-token logits.
    pub fn decode(&self, token: u32, cache: &mut KvCache) -> Result<Vec<f32>> {
        let pos = cache.len;
        if pos >= self.cfg.max_seq {
            bail!("KV cache full ({} positions)", self.cfg.max_seq);
        }
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        let mut x = self.tok_emb.row(token as usize).to_vec();
        if let Some(pe) = &self.pos_emb {
            for (a, &b) in x.iter_mut().zip(pe.row(pos)) {
                *a += b;
            }
        }
        let mut xn = vec![0.0f32; e];
        for (li, l) in self.layers.iter().enumerate() {
            self.norm(&x, &l.ln1, &mut xn);
            let mut q = vec![0.0f32; e];
            let mut k = vec![0.0f32; e];
            let mut v = vec![0.0f32; e];
            gemm_packed_into(&xn, &l.wq, &mut q, 1);
            gemm_packed_into(&xn, &l.wk, &mut k, 1);
            gemm_packed_into(&xn, &l.wv, &mut v, 1);
            if self.cfg.kind == ModelKind::Llama {
                for hh in 0..h {
                    ops::rope_inplace(&mut q[hh * hd..(hh + 1) * hd], pos, 10000.0);
                    ops::rope_inplace(&mut k[hh * hd..(hh + 1) * hd], pos, 10000.0);
                }
            }
            // write K/V at pos
            for hh in 0..h {
                let dst = hh * self.cfg.max_seq * hd + pos * hd;
                cache.k[li][dst..dst + hd].copy_from_slice(&k[hh * hd..(hh + 1) * hd]);
                cache.v[li][dst..dst + hd].copy_from_slice(&v[hh * hd..(hh + 1) * hd]);
            }
            let att = decode_attention(
                &q,
                &cache.k[li],
                &cache.v[li],
                h,
                self.cfg.max_seq,
                hd,
                pos,
            );
            let mut proj = vec![0.0f32; e];
            gemm_packed_into(&att, &l.wo, &mut proj, 1);
            for (a, b) in x.iter_mut().zip(&proj) {
                *a += b;
            }
            self.norm(&x, &l.ln2, &mut xn);
            let y = self.mlp(&Tensor::new(&[1, e], xn.clone()), l);
            for (a, &b) in x.iter_mut().zip(y.data()) {
                *a += b;
            }
        }
        cache.len = pos + 1;
        let mut last = vec![0.0f32; e];
        self.norm(&x, &self.final_norm, &mut last);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        gemm_packed_into(&last, &self.lm_head, &mut logits, 1);
        Ok(logits)
    }

    /// One batched decode step over `B` independent sessions: append
    /// `tokens[i]` at position `caches[i].len` and return the next-token
    /// logits of every session.
    ///
    /// The B hidden states are stacked into one `(B × d_model)` activation
    /// matrix so the QKV/output projections, the dense/sparse/fused MLP and
    /// the LM head each run as a **single** packed GEMM or BSpMM over the
    /// prepacked weights — every streamed weight panel / BCSC block is
    /// amortized over B rows instead of being re-read per session, which is
    /// what turns the decode round from latency-bound GEMV chains into a
    /// throughput-bound GEMM (the serving lever behind the paper's Fig. 6).
    /// Attention stays per-sequence over each session's KV cache,
    /// parallelized across `(session, head)` items on the thread pool.
    ///
    /// Outputs are bit-identical to calling [`Engine::decode`] once per
    /// session: the packed micro-kernel accumulates every output element
    /// serially over the depth dimension regardless of how many rows share
    /// the tile, and the per-head attention body is the exact code the
    /// sequential path runs.
    ///
    /// Validation is all-or-nothing: if any session's cache is full or any
    /// token is out of vocab, an error is returned **before** any cache or
    /// activation is touched, so the caller can retry with the offending
    /// session removed. Ragged batches are the caller's concern — pass only
    /// the still-active sessions each round; `B = 0` is a no-op.
    ///
    /// # Panics
    /// If `tokens.len() != caches.len()`.
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(
            tokens.len(),
            caches.len(),
            "decode_batch: {} tokens vs {} caches",
            tokens.len(),
            caches.len()
        );
        let bsz = tokens.len();
        if bsz == 0 {
            return Ok(Vec::new());
        }
        let (e, h, hd) = (self.cfg.emb, self.cfg.heads, self.cfg.head_dim());
        let max_seq = self.cfg.max_seq;
        // all-or-nothing validation before any state is mutated
        for (i, (&t, c)) in tokens.iter().zip(caches.iter()).enumerate() {
            if c.len >= max_seq {
                bail!("decode_batch session {i}: KV cache full ({max_seq} positions)");
            }
            if t as usize >= self.cfg.vocab {
                bail!("decode_batch session {i}: token {t} out of vocab {}", self.cfg.vocab);
            }
        }
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        // embed the B new tokens into one (B, e) activation matrix
        let mut x = Tensor::zeros(&[bsz, e]);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.tok_emb.row(t as usize));
            if let Some(pe) = &self.pos_emb {
                for (a, &b) in x.row_mut(i).iter_mut().zip(pe.row(positions[i])) {
                    *a += b;
                }
            }
        }
        let mut xn = Tensor::zeros(&[bsz, e]);
        // projection/attention activations come from the thread-local
        // scratch arena, so the per-layer hot loop recycles its buffers
        // after the first round (q/k/v/proj are re-zeroed per layer below;
        // att is fully overwritten by the attention fan-out)
        let mut q = scratch::take_uninit(bsz * e);
        let mut k = scratch::take_uninit(bsz * e);
        let mut v = scratch::take_uninit(bsz * e);
        let mut att = scratch::take_uninit(bsz * e);
        let mut proj = scratch::take_uninit(bsz * e);
        for (li, l) in self.layers.iter().enumerate() {
            // x and xn are distinct tensors, so the norm borrows directly —
            // no per-row copies on the batched hot path
            for i in 0..bsz {
                self.norm(x.row(i), &l.ln1, xn.row_mut(i));
            }
            // one batched GEMM per projection (gemm accumulates: zero first)
            q.fill(0.0);
            k.fill(0.0);
            v.fill(0.0);
            gemm_packed_into(xn.data(), &l.wq, &mut q, bsz);
            gemm_packed_into(xn.data(), &l.wk, &mut k, bsz);
            gemm_packed_into(xn.data(), &l.wv, &mut v, bsz);
            if self.cfg.kind == ModelKind::Llama {
                for i in 0..bsz {
                    let pos = positions[i];
                    for hh in 0..h {
                        let o = i * e + hh * hd;
                        ops::rope_inplace(&mut q[o..o + hd], pos, 10000.0);
                        ops::rope_inplace(&mut k[o..o + hd], pos, 10000.0);
                    }
                }
            }
            // write each session's K/V at its own position
            for (i, cache) in caches.iter_mut().enumerate() {
                let (kr, vr) = (&k[i * e..(i + 1) * e], &v[i * e..(i + 1) * e]);
                for hh in 0..h {
                    let dst = hh * max_seq * hd + positions[i] * hd;
                    cache.k[li][dst..dst + hd].copy_from_slice(&kr[hh * hd..(hh + 1) * hd]);
                    cache.v[li][dst..dst + hd].copy_from_slice(&vr[hh * hd..(hh + 1) * hd]);
                }
            }
            // per-sequence attention, (session, head) items across the pool
            {
                let caches_ref: &[KvCache] = &*caches;
                let positions_ref: &[usize] = &positions;
                let qd: &[f32] = &q;
                let att_base = att.as_mut_ptr() as usize;
                threadpool::parallel_for(bsz * h, |t| {
                    let (i, hh) = (t / h, t % h);
                    let c = &caches_ref[i];
                    // SAFETY: each (session, head) item owns the disjoint
                    // span att[i, hh*hd..(hh+1)*hd]; parallel_for blocks
                    // until all items finish.
                    let orow = unsafe {
                        std::slice::from_raw_parts_mut(
                            (att_base as *mut f32).add(i * e + hh * hd),
                            hd,
                        )
                    };
                    decode_head_into(
                        &qd[i * e + hh * hd..i * e + (hh + 1) * hd],
                        &c.k[li][hh * max_seq * hd..],
                        &c.v[li][hh * max_seq * hd..],
                        hd,
                        positions_ref[i],
                        orow,
                    );
                });
            }
            proj.fill(0.0);
            gemm_packed_into(&att, &l.wo, &mut proj, bsz);
            for (a, &b) in x.data_mut().iter_mut().zip(proj.iter()) {
                *a += b;
            }
            for i in 0..bsz {
                self.norm(x.row(i), &l.ln2, xn.row_mut(i));
            }
            let y = self.mlp(&xn, l);
            x.add_inplace(&y);
        }
        for c in caches.iter_mut() {
            c.len += 1;
        }
        // final norm + one batched LM-head GEMM (both scratch-backed)
        let mut last = scratch::take_uninit(bsz * e);
        for i in 0..bsz {
            self.norm(x.row(i), &self.final_norm, &mut last[i * e..(i + 1) * e]);
        }
        let vocab = self.cfg.vocab;
        let mut logits = scratch::take_zeroed(bsz * vocab);
        gemm_packed_into(&last, &self.lm_head, &mut logits, bsz);
        Ok(logits.chunks(vocab).map(|c| c.to_vec()).collect())
    }

    /// Greedy argmax over logits.
    pub fn argmax(logits: &[f32]) -> u32 {
        let mut best = 0usize;
        for i in 1..logits.len() {
            if logits[i] > logits[best] {
                best = i;
            }
        }
        best as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_cfg(kind: ModelKind) -> NativeConfig {
        NativeConfig {
            name: "t".into(),
            kind,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 2,
            heads: 2,
            max_seq: 16,
            block: 8,
        }
    }

    fn test_params(cfg: &NativeConfig, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        if cfg.kind == ModelKind::Gpt2 {
            s.insert("pos_emb".into(), Tensor::randn(&[cfg.max_seq, e], 0.1, &mut rng));
        }
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        s
    }

    fn random_masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
        let mut rng = Rng::new(seed);
        let mut m = BTreeMap::new();
        for i in 0..cfg.layers {
            for (n, r, c) in cfg.mlp_shapes() {
                m.insert(
                    format!("layer{i}.{n}"),
                    BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
                );
            }
        }
        m
    }

    #[test]
    fn decode_matches_prefill_both_kinds() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            let cfg = test_cfg(kind);
            let params = test_params(&cfg, 1);
            let masks = random_masks(&cfg, 0.3, 2);
            let eng = Engine::new(cfg.clone(), &params, &masks, MlpMode::Dense).unwrap();
            let tokens: Vec<u32> = vec![3, 7, 11, 2, 9];
            // full prefill
            let mut c1 = eng.new_cache();
            let full = eng.prefill(&tokens, &mut c1).unwrap();
            // prefill on the prefix, then decode the last token
            let mut c2 = eng.new_cache();
            eng.prefill(&tokens[..4], &mut c2).unwrap();
            let step = eng.decode(tokens[4], &mut c2).unwrap();
            for (a, b) in full.iter().zip(&step) {
                assert!((a - b).abs() < 1e-3, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_and_dense_modes_agree() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            let cfg = test_cfg(kind);
            let params = test_params(&cfg, 3);
            let masks = random_masks(&cfg, 0.5, 4);
            let dense = Engine::new(cfg.clone(), &params, &masks, MlpMode::Dense).unwrap();
            let sparse = Engine::new(cfg.clone(), &params, &masks, MlpMode::Sparse).unwrap();
            let tokens: Vec<u32> = vec![1, 5, 9];
            let mut cd = dense.new_cache();
            let mut cs = sparse.new_cache();
            let ld = dense.prefill(&tokens, &mut cd).unwrap();
            let ls = sparse.prefill(&tokens, &mut cs).unwrap();
            for (a, b) in ld.iter().zip(&ls) {
                assert!((a - b).abs() < 1e-3, "{kind:?} prefill: {a} vs {b}");
            }
            let dd = dense.decode(2, &mut cd).unwrap();
            let ds = sparse.decode(2, &mut cs).unwrap();
            for (a, b) in dd.iter().zip(&ds) {
                assert!((a - b).abs() < 1e-3, "{kind:?} decode: {a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_mode_shrinks_mlp_bytes() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 5);
        let dense_masks = BTreeMap::new();
        let sparse_masks = random_masks(&cfg, 0.75, 6);
        let dense = Engine::new(cfg.clone(), &params, &dense_masks, MlpMode::Sparse).unwrap();
        let sparse = Engine::new(cfg.clone(), &params, &sparse_masks, MlpMode::Sparse).unwrap();
        assert!(sparse.mlp_weight_bytes() < dense.mlp_weight_bytes() / 2);
    }

    /// The tentpole guarantee: batched decode is **bit-identical** to
    /// sequential decode — same logits bit patterns, same greedy streams —
    /// across ragged batch sizes (sessions finishing mid-round), both model
    /// kinds and both MLP modes.
    #[test]
    fn decode_batch_bitwise_matches_sequential_ragged() {
        for kind in [ModelKind::Gpt2, ModelKind::Llama] {
            for mode in [MlpMode::Dense, MlpMode::Sparse] {
                let cfg = test_cfg(kind);
                let params = test_params(&cfg, 11);
                let masks = random_masks(&cfg, 0.5, 12);
                let eng = Engine::new(cfg.clone(), &params, &masks, mode).unwrap();
                let prompts: Vec<Vec<u32>> = vec![vec![3, 7, 11], vec![2], vec![9, 4, 1, 5]];
                // per-session decode budgets force sessions to retire
                // mid-round: batch shrinks 3 -> 2 -> 1
                let budgets = [6usize, 2, 4];
                // sequential greedy reference
                let mut seq_streams: Vec<Vec<u32>> = Vec::new();
                let mut seq_logits: Vec<Vec<f32>> = Vec::new();
                for (p, &n) in prompts.iter().zip(&budgets) {
                    let mut cache = eng.new_cache();
                    let logits = eng.prefill(p, &mut cache).unwrap();
                    let mut tok = Engine::argmax(&logits);
                    let mut stream = vec![tok];
                    let mut last = Vec::new();
                    for _ in 0..n {
                        last = eng.decode(tok, &mut cache).unwrap();
                        tok = Engine::argmax(&last);
                        stream.push(tok);
                    }
                    seq_streams.push(stream);
                    seq_logits.push(last);
                }
                // batched greedy over the shrinking active set
                let mut caches: Vec<KvCache> = Vec::new();
                let mut streams: Vec<Vec<u32>> = Vec::new();
                for p in &prompts {
                    let mut cache = eng.new_cache();
                    let logits = eng.prefill(p, &mut cache).unwrap();
                    streams.push(vec![Engine::argmax(&logits)]);
                    caches.push(cache);
                }
                let mut slots: Vec<Option<KvCache>> = caches.into_iter().map(Some).collect();
                let mut last_logits: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
                loop {
                    let live: Vec<usize> = (0..prompts.len())
                        .filter(|&i| streams[i].len() <= budgets[i])
                        .collect();
                    if live.is_empty() {
                        break;
                    }
                    let toks: Vec<u32> = live.iter().map(|&i| *streams[i].last().unwrap()).collect();
                    let mut round: Vec<KvCache> =
                        live.iter().map(|&i| slots[i].take().unwrap()).collect();
                    let logits = eng.decode_batch(&toks, &mut round).unwrap();
                    for ((&i, cache), l) in live.iter().zip(round).zip(logits) {
                        streams[i].push(Engine::argmax(&l));
                        last_logits[i] = l;
                        slots[i] = Some(cache);
                    }
                }
                for i in 0..prompts.len() {
                    assert_eq!(
                        streams[i], seq_streams[i],
                        "{kind:?}/{mode:?} session {i}: greedy streams diverged"
                    );
                    // bit-identical, not approximately equal
                    let same_bits = last_logits[i]
                        .iter()
                        .zip(&seq_logits[i])
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same_bits, "{kind:?}/{mode:?} session {i}: logits bits differ");
                }
            }
        }
    }

    #[test]
    fn decode_batch_single_session_equals_decode() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 21);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut c1 = eng.new_cache();
        let mut c2 = eng.new_cache();
        eng.prefill(&[5, 9], &mut c1).unwrap();
        eng.prefill(&[5, 9], &mut c2).unwrap();
        let a = eng.decode(3, &mut c1).unwrap();
        let b = eng.decode_batch(&[3], std::slice::from_mut(&mut c2)).unwrap();
        assert!(a.iter().zip(&b[0]).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert_eq!(c1.len, c2.len);
    }

    #[test]
    fn decode_batch_empty_is_noop() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 22);
        let eng = Engine::new(cfg, &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        assert!(eng.decode_batch(&[], &mut []).unwrap().is_empty());
    }

    #[test]
    #[should_panic(expected = "decode_batch: 2 tokens vs 1 caches")]
    fn decode_batch_panics_on_shape_mismatch() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 23);
        let eng = Engine::new(cfg, &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut cache = eng.new_cache();
        eng.prefill(&[1], &mut cache).unwrap();
        let _ = eng.decode_batch(&[1, 2], std::slice::from_mut(&mut cache));
    }

    #[test]
    fn decode_batch_validates_before_mutating() {
        let cfg = test_cfg(ModelKind::Llama);
        let params = test_params(&cfg, 24);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        // session 0 healthy, session 1 with a full cache
        let mut a = eng.new_cache();
        eng.prefill(&[1, 2], &mut a).unwrap();
        let mut b = eng.new_cache();
        eng.prefill(&vec![1; cfg.max_seq], &mut b).unwrap();
        let mut caches = vec![a, b];
        assert!(eng.decode_batch(&[1, 1], &mut caches).is_err());
        // all-or-nothing: the healthy session's cache must be untouched
        assert_eq!(caches[0].len, 2);
        assert_eq!(caches[1].len, cfg.max_seq);
        // out-of-vocab token also rejected upfront
        let err = eng.decode_batch(&[999], &mut caches[..1]).unwrap_err();
        assert!(err.to_string().contains("out of vocab"), "{err}");
        assert_eq!(caches[0].len, 2);
    }

    #[test]
    fn cache_overflow_and_bad_token_rejected() {
        let cfg = test_cfg(ModelKind::Gpt2);
        let params = test_params(&cfg, 7);
        let eng = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense).unwrap();
        let mut c = eng.new_cache();
        assert!(eng.prefill(&[999], &mut c).is_err());
        let long: Vec<u32> = vec![1; cfg.max_seq + 1];
        assert!(eng.prefill(&long, &mut c).is_err());
        eng.prefill(&vec![1; cfg.max_seq], &mut c).unwrap();
        assert!(eng.decode(1, &mut c).is_err());
    }
}
