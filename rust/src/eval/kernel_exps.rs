//! Kernel & end-to-end wall-clock experiments: Figs. 4, 5, 6.
//!
//! All three run the native Rust kernel stack — the CPU analogue of the
//! paper's Triton kernel vs min(cuBLAS, CUTLASS) comparison. The *shape*
//! of the result is what reproduces: a crossover at moderate sparsity, a
//! `~1/(1-s)` climb after it, bigger wins at bigger shapes, and an
//! end-to-end inference gain once the MLP dominates.

use anyhow::Result;

use crate::kernels::bspmm::{bspmm, bspmm_flops, bspmm_into, bspmm_into_ref};
use crate::kernels::csr_spmm::csr_spmm;
use crate::kernels::gemm::{gemm, gemm_flops, gemm_into, gemm_into_ref, gemm_naive};
use crate::model::config::{paper_catalog, ModelKind, NativeConfig};
use crate::model::engine::{Engine, MlpMode};
use crate::model::params::ParamStore;
use crate::sparse::{Bcsc, BlockMask, Csr};
use crate::tensor::Tensor;
use crate::testkit::bench::{bench_cfg, black_box, fmt_flops, JsonReport, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::time::Duration;

fn meas<F: FnMut()>(name: &str, quick: bool, mut f: F) -> f64 {
    let budget = if quick {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(400)
    };
    bench_cfg(name, budget, if quick { 3 } else { 5 }, &mut f).secs()
}

/// `blast exp kernels` — seed-vs-packed kernel A/B harness.
///
/// Measures the retained seed kernels (`gemm_into_ref`, `bspmm_into_ref`)
/// against the packed micro-kernel engine on fig4-shaped operands, checks
/// both against the naive/masked oracles, prints the table and writes the
/// machine-readable `BENCH_kernels.json` (override with `--out`). This is
/// the perf-trajectory baseline every future kernel PR is compared to;
/// PR 1's acceptance gate is speedup ≥ 1.5× on dense GEMM and BSpMM.
pub fn kernels(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_kernels.json");
    let m = args.get_usize("seq", 256);
    let embs = args.get_usize_list("embs", if quick { &[256] } else { &[512, 1024] });
    let blocks = args.get_usize_list("blocks", &[32, 64, 128]);
    let sparsities = args.get_f64_list("sparsities", &[0.0, 0.8, 0.9, 0.95]);

    let mut report = JsonReport::new("kernels");
    report.meta("isa", Json::str(crate::kernels::simd::dispatch().isa.name()));
    report.meta(
        "threads",
        Json::num(crate::util::threadpool::global().workers() as f64),
    );
    report.meta("seq", Json::num(m as f64));
    let mut table = Table::new(
        "Seed vs packed kernel engine (PR1 gate: >= 1.5x on gemm & bspmm)",
        &["kernel", "shape", "block", "sparsity", "seed", "packed", "speedup", "eff-GFLOP/s", "oracle-diff"],
    );
    let mut rng = Rng::new(0xB1A5);
    for &emb in &embs {
        let n = 4 * emb;
        let x = Tensor::randn(&[m, emb], 1.0, &mut rng);
        let wd = Tensor::randn(&[emb, n], 1.0, &mut rng);
        // oracle check on the smallest shape only (naive is O(mkn) scalar)
        let oracle_diff = if emb == embs[0] {
            let fast = gemm(&x, &wd);
            let slow = gemm_naive(&x, &wd);
            fast.max_abs_diff(&slow)
        } else {
            f32::NAN
        };
        let mut c = vec![0.0f32; m * n];
        let t_ref = meas("gemm-ref", quick, || {
            gemm_into_ref(x.data(), wd.data(), &mut c, m, emb, n);
            black_box(&c);
        });
        let t_new = meas("gemm-packed", quick, || {
            gemm_into(x.data(), wd.data(), &mut c, m, emb, n);
            black_box(&c);
        });
        let gflops = gemm_flops(m, emb, n) / t_new / 1e9;
        push_ab_row(
            &mut table,
            &mut report,
            "gemm",
            m,
            emb,
            n,
            0,
            0.0,
            t_ref,
            t_new,
            gflops,
            oracle_diff,
        );
        for &b in &blocks {
            for &s in &sparsities {
                let mask = BlockMask::random(emb / b, n / b, s, &mut rng);
                let w = Bcsc::from_dense(&wd, &mask, b);
                let oracle_diff = if emb == embs[0] && b == blocks[0] {
                    let got = bspmm(&x, &w);
                    let mut masked = wd.clone();
                    mask.apply_to(masked.data_mut(), b);
                    got.max_abs_diff(&gemm_naive(&x, &masked))
                } else {
                    f32::NAN
                };
                let mut y = vec![0.0f32; m * n];
                let t_ref = meas("bspmm-ref", quick, || {
                    bspmm_into_ref(x.data(), &w, &mut y, m);
                    black_box(&y);
                });
                let t_new = meas("bspmm-packed", quick, || {
                    bspmm_into(x.data(), &w, &mut y, m);
                    black_box(&y);
                });
                let gflops = bspmm_flops(m, &w) / t_new / 1e9;
                push_ab_row(
                    &mut table,
                    &mut report,
                    "bspmm",
                    m,
                    emb,
                    n,
                    b,
                    s,
                    t_ref,
                    t_new,
                    gflops,
                    oracle_diff,
                );
            }
        }
    }
    table.print();
    report.write(std::path::Path::new(&out_path))?;
    println!("\nwrote {} rows to {out_path}", report.len());
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn push_ab_row(
    table: &mut Table,
    report: &mut JsonReport,
    kernel: &str,
    m: usize,
    k: usize,
    n: usize,
    block: usize,
    sparsity: f64,
    t_ref: f64,
    t_new: f64,
    gflops: f64,
    oracle_diff: f32,
) {
    table.row(&[
        kernel.to_string(),
        format!("{m}x{k}x{n}"),
        if block == 0 { "-".into() } else { block.to_string() },
        format!("{:.0}%", sparsity * 100.0),
        crate::testkit::bench::fmt_time(t_ref),
        crate::testkit::bench::fmt_time(t_new),
        format!("{:.2}x", t_ref / t_new),
        format!("{gflops:.2}"),
        if oracle_diff.is_nan() {
            "-".into()
        } else {
            format!("{oracle_diff:.2e}")
        },
    ]);
    let mut row = vec![
        ("kernel", Json::str(kernel)),
        ("m", Json::num(m as f64)),
        ("k", Json::num(k as f64)),
        ("n", Json::num(n as f64)),
        ("block", Json::num(block as f64)),
        ("sparsity", Json::num(sparsity)),
        ("seed_ns", Json::num(t_ref * 1e9)),
        ("packed_ns", Json::num(t_new * 1e9)),
        ("speedup", Json::num(t_ref / t_new)),
        ("eff_gflops", Json::num(gflops)),
    ];
    if !oracle_diff.is_nan() {
        row.push(("oracle_max_diff", Json::num(oracle_diff as f64)));
    }
    report.push(Json::obj(row));
}

/// Fig. 4: BSpMM speedup over the dense baseline across (emb, block,
/// sparsity); CSR shown as the unstructured baseline.
pub fn fig4(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let embs = args.get_usize_list("embs", if quick { &[512] } else { &[512, 1024, 2048] });
    let seq = args.get_usize("seq", 256);
    let blocks = args.get_usize_list("blocks", &[32, 64, 128]);
    let sparsities = args.get_f64_list("sparsities", &[0.0, 0.5, 0.7, 0.8, 0.9, 0.95]);

    let mut table = Table::new(
        "Fig.4 — BSpMM speedup vs dense GEMM (paper: up to 16.7x @95%, crossover ~50%)",
        &["emb", "n", "block", "sparsity", "dense", "bspmm", "speedup", "csr-speedup", "eff-GFLOP/s"],
    );
    let mut rng = Rng::new(4);
    for &emb in &embs {
        let n = 4 * emb;
        let x = Tensor::randn(&[seq, emb], 1.0, &mut rng);
        let wd = Tensor::randn(&[emb, n], 1.0, &mut rng);
        let t_dense = meas("dense", quick, || {
            black_box(gemm(&x, &wd));
        });
        for &b in &blocks {
            for &s in &sparsities {
                let mask = BlockMask::random(emb / b, n / b, s, &mut rng);
                let w = Bcsc::from_dense(&wd, &mask, b);
                let t_sp = meas("bspmm", quick, || {
                    black_box(bspmm(&x, &w));
                });
                // CSR baseline only for the smallest block row (it is
                // block-size independent)
                let csr_speedup = if b == blocks[0] {
                    let wcsr = Csr::random(emb, n, s, &mut rng);
                    let t_csr = meas("csr", quick, || {
                        black_box(csr_spmm(&x, &wcsr));
                    });
                    format!("{:.2}x", t_dense / t_csr)
                } else {
                    "-".to_string()
                };
                table.row(&[
                    emb.to_string(),
                    n.to_string(),
                    b.to_string(),
                    format!("{:.0}%", s * 100.0),
                    crate::testkit::bench::fmt_time(t_dense),
                    crate::testkit::bench::fmt_time(t_sp),
                    format!("{:.2}x", t_dense / t_sp),
                    csr_speedup,
                    fmt_flops(bspmm_flops(seq, &w) / t_sp),
                ]);
            }
        }
    }
    table.print();
    println!(
        "\npaper shape check: speedup grows with sparsity & size; ≥~50% sparsity beats dense;\n\
         dense GEMM reference: {} at emb={} (m={seq})",
        fmt_flops(gemm_flops(seq, embs[0], 4 * embs[0]) / meas("ref", true, || {
            let x = Tensor::randn(&[seq, embs[0]], 1.0, &mut Rng::new(9));
            let w = Tensor::randn(&[embs[0], 4 * embs[0]], 1.0, &mut Rng::new(10));
            black_box(gemm(&x, &w));
        })),
        embs[0]
    );
    Ok(())
}

/// Fig. 5: fused sparse MLP speedup at (scaled) Llama-family geometries.
pub fn fig5(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let block = args.get_usize("block", 128);
    let sparsities = args.get_f64_list("sparsities", &[0.7, 0.8, 0.9, 0.95]);
    // (geometry, scale divisor, seq) — large members run at reduced width;
    // the MLP speedup ratio is scale-free (both sides compute-bound)
    let plan: Vec<(&str, usize, usize)> = if quick {
        vec![("Llama-3.2-1B", 2, 32), ("Llama-3.1-8B", 4, 16)]
    } else {
        vec![
            ("Llama-3.2-1B", 1, 64),
            ("Llama-3.2-3B", 1, 48),
            ("Llama-3.1-8B", 2, 32),
            ("Llama-3.1-70B", 4, 16),
            ("Llama-3.1-405B", 8, 16),
        ]
    };
    let mut table = Table::new(
        "Fig.5 — MLP block speedup, Llama family @128x128 (paper: 2x @70%, up to 8.8x @405B)",
        &["model", "emb(scaled)", "ffn(scaled)", "sparsity", "dense", "sparse", "speedup"],
    );
    let mut rng = Rng::new(5);
    for (name, div, seq) in plan {
        let g = paper_catalog().into_iter().find(|g| g.name == name).unwrap();
        let emb = (g.emb / div).div_ceil(block) * block;
        let ffn = (g.ffn / div).div_ceil(block) * block;
        let x = Tensor::randn(&[seq, emb], 0.5, &mut rng);
        let w1d = Tensor::randn(&[emb, ffn], 0.02, &mut rng);
        let w2d = Tensor::randn(&[emb, ffn], 0.02, &mut rng);
        let w3d = Tensor::randn(&[ffn, emb], 0.02, &mut rng);
        let dense_mask1 = BlockMask::ones(emb / block, ffn / block);
        let dense_mask3 = BlockMask::ones(ffn / block, emb / block);
        let w1 = Bcsc::from_dense(&w1d, &dense_mask1, block);
        let w2 = Bcsc::from_dense(&w2d, &dense_mask1, block);
        let w3 = Bcsc::from_dense(&w3d, &dense_mask3, block);
        let t_dense = meas("mlp-dense", quick, || {
            black_box(crate::kernels::bspmm::fused_mlp_sparse(
                &x,
                &crate::kernels::bspmm::FusedMlpWeights {
                    w1: &w1,
                    w2: &w2,
                    w3: &w3,
                },
            ));
        });
        for &s in &sparsities {
            let m1 = BlockMask::random(emb / block, ffn / block, s, &mut rng);
            let m2 = BlockMask::random(emb / block, ffn / block, s, &mut rng);
            let m3 = BlockMask::random(ffn / block, emb / block, s, &mut rng);
            let s1 = Bcsc::from_dense(&w1d, &m1, block);
            let s2 = Bcsc::from_dense(&w2d, &m2, block);
            let s3 = Bcsc::from_dense(&w3d, &m3, block);
            let t_sp = meas("mlp-sparse", quick, || {
                black_box(crate::kernels::bspmm::fused_mlp_sparse(
                    &x,
                    &crate::kernels::bspmm::FusedMlpWeights {
                        w1: &s1,
                        w2: &s2,
                        w3: &s3,
                    },
                ));
            });
            table.row(&[
                name.to_string(),
                emb.to_string(),
                ffn.to_string(),
                format!("{:.0}%", s * 100.0),
                crate::testkit::bench::fmt_time(t_dense),
                crate::testkit::bench::fmt_time(t_sp),
                format!("{:.2}x", t_dense / t_sp),
            ]);
        }
    }
    table.print();
    Ok(())
}

/// The native Llama twin used for Fig. 6 (bigger than the AOT twins so the
/// MLP dominates decode time, as in the real Llama-3.2-1B).
pub fn fig6_config(block: usize) -> NativeConfig {
    NativeConfig {
        name: "llama1b-native".into(),
        kind: ModelKind::Llama,
        vocab: 4096,
        emb: 1024,
        ffn: 4096,
        layers: 6,
        heads: 8,
        max_seq: 256,
        block,
    }
}

pub fn fig6_params(cfg: &NativeConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut s = ParamStore::new();
    let e = cfg.emb;
    s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.02, &mut rng));
    for i in 0..cfg.layers {
        let p = |n: &str| format!("layer{i}.{n}");
        s.insert(p("ln1"), Tensor::full(&[e], 1.0));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            s.insert(p(w), Tensor::randn(&[e, e], 0.02, &mut rng));
        }
        s.insert(p("ln2"), Tensor::full(&[e], 1.0));
        for (n, r, c) in cfg.mlp_shapes() {
            s.insert(p(n), Tensor::randn(&[r, c], 0.02, &mut rng));
        }
    }
    s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
    s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.02, &mut rng));
    s
}

pub fn random_masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for i in 0..cfg.layers {
        for (n, r, c) in cfg.mlp_shapes() {
            m.insert(
                format!("layer{i}.{n}"),
                BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
            );
        }
    }
    m
}

/// Fig. 6: end-to-end decode speedup of the sparse engine vs the dense one.
pub fn fig6(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let blocks = args.get_usize_list("blocks", if quick { &[128] } else { &[32, 64, 128] });
    let sparsities = args.get_f64_list("sparsities", &[0.7, 0.9, 0.95]);
    let new_tokens = args.get_usize("tokens", if quick { 16 } else { 48 });
    let prompt: Vec<u32> = (0..16).map(|i| (i * 37 % 4096) as u32).collect();

    let mut table = Table::new(
        "Fig.6 — end-to-end inference speedup, Llama twin (paper: 1.3x @70%, 1.6x @95%)",
        &["block", "sparsity", "dense tok/s", "sparse tok/s", "speedup"],
    );
    for &b in &blocks {
        let cfg = fig6_config(b);
        let params = fig6_params(&cfg, 6);
        // dense reference at this block size (all-ones masks)
        let dense = Engine::new(cfg.clone(), &params, &BTreeMap::new(), MlpMode::Dense)?;
        let t_dense = decode_time(&dense, &prompt, new_tokens)?;
        for &s in &sparsities {
            let masks = random_masks(&cfg, s, 60 + b as u64);
            let sparse = Engine::new(cfg.clone(), &params, &masks, MlpMode::Sparse)?;
            let t_sp = decode_time(&sparse, &prompt, new_tokens)?;
            table.row(&[
                format!("{b}x{b}"),
                format!("{:.0}%", s * 100.0),
                format!("{:.1}", new_tokens as f64 / t_dense),
                format!("{:.1}", new_tokens as f64 / t_sp),
                format!("{:.2}x", t_dense / t_sp),
            ]);
        }
    }
    table.print();
    Ok(())
}

fn decode_time(engine: &Engine, prompt: &[u32], new_tokens: usize) -> Result<f64> {
    // warmup + measurement run
    for _ in 0..1 {
        let mut cache = engine.new_cache();
        engine.prefill(prompt, &mut cache)?;
        engine.decode(1, &mut cache)?;
    }
    let mut cache = engine.new_cache();
    let logits = engine.prefill(prompt, &mut cache)?;
    let mut tok = Engine::argmax(&logits);
    let t0 = std::time::Instant::now();
    for _ in 0..new_tokens {
        let logits = engine.decode(tok, &mut cache)?;
        tok = Engine::argmax(&logits);
    }
    Ok(t0.elapsed().as_secs_f64())
}
