//! Experiment drivers — one per table/figure of the paper (DESIGN.md §5).
//!
//! Each driver regenerates its table/figure's rows on this testbed and
//! prints them next to the paper's reference values, so `blast exp <id>`
//! output can be pasted into EXPERIMENTS.md. Drivers accept `--steps`,
//! `--quick` and experiment-specific flags; defaults are sized for minutes,
//! not hours.
//!
//! | id      | artifact                                  | driver            |
//! |---------|-------------------------------------------|-------------------|
//! | kernels | seed-vs-packed A/B → BENCH_kernels.json   | [`kernel_exps`]   |
//! | serve | batched-vs-seq decode → BENCH_serve.json   | [`serve_exps`]    |
//! | attention | tiled/paged attention A/B + KV memory → BENCH_attention.json | [`attention_exps`] |
//! | pretrain | dense-vs-sparse train step A/B → BENCH_pretrain.json | [`pretrain_exps`] |
//! | chaos | seeded fault-injection serving sweep (liveness invariants) | [`chaos_exps`] |
//! | fig4  | BSpMM kernel speedup sweep                 | [`kernel_exps`]   |
//! | fig5  | Llama-family MLP speedup                   | [`kernel_exps`]   |
//! | fig6  | end-to-end inference speedup               | [`kernel_exps`]   |
//! | fig7  | GPU-count memory model                     | [`memory_exps`]   |
//! | tab1  | GLUE fine-tuning robustness                | [`classify_exps`] |
//! | tab2  | pretraining time + perplexity              | [`pretrain_exps`] |
//! | fig8  | time-per-iteration curves                  | [`pretrain_exps`] |
//! | tab3  | ViT accuracy vs sparsity                   | [`classify_exps`] |
//! | fig9  | ViT accuracy per PFLOP                     | [`classify_exps`] |
//! | tab4  | perplexity vs block size                   | [`pretrain_exps`] |
//! | fig10 | regrown-block ratio vs block size          | [`pretrain_exps`] |
//! | tab5  | perplexity vs step_size                    | [`pretrain_exps`] |
//! | tab6  | perplexity vs sparsity decay d             | [`pretrain_exps`] |
//! | fig11 | dense-layer placement (left vs right)      | [`pretrain_exps`] |

pub mod attention_exps;
pub mod chaos_exps;
pub mod classify_exps;
pub mod kernel_exps;
pub mod memory_exps;
pub mod pretrain_exps;
pub mod serve_exps;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub const ALL: &[&str] = &[
    "kernels", "serve", "attention", "pretrain", "chaos", "fig4", "fig5", "fig6", "fig7",
    "tab1", "tab2", "fig8", "tab3", "fig9", "tab4", "fig10", "tab5", "tab6", "fig11",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "kernels" => kernel_exps::kernels(args),
        "serve" => serve_exps::serve(args),
        "attention" => attention_exps::attention(args),
        "pretrain" => pretrain_exps::pretrain_ab(args),
        "chaos" => chaos_exps::chaos(args),
        "fig4" => kernel_exps::fig4(args),
        "fig5" => kernel_exps::fig5(args),
        "fig6" => kernel_exps::fig6(args),
        "fig7" => memory_exps::fig7(args),
        "tab1" => classify_exps::tab1(args),
        "tab2" => pretrain_exps::tab2(args),
        "fig8" => pretrain_exps::fig8(args),
        "tab3" => classify_exps::tab3(args),
        "fig9" => classify_exps::fig9(args),
        "tab4" => pretrain_exps::tab4(args),
        "fig10" => pretrain_exps::fig10(args),
        "tab5" => pretrain_exps::tab5(args),
        "tab6" => pretrain_exps::tab6(args),
        "fig11" => pretrain_exps::fig11(args),
        "all" => {
            for e in ALL {
                println!("\n################ {e} ################");
                run(e, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; available: {ALL:?} or 'all'"),
    }
}
