//! Classification experiments: Table 1 (GLUE fine-tuning), Table 3 (ViT
//! accuracy vs sparsity), Fig. 9 (ViT accuracy per PFLOP).

use anyhow::Result;

use crate::data::cifar::CifarSim;
use crate::data::glue::{GlueGen, GlueTask};
use crate::model::config::{ModelKind, NativeConfig};
use crate::perf::flops;
use crate::runtime::Runtime;
use crate::sparsify::SparsitySchedule;
use crate::testkit::bench::Table;
use crate::train::classify::{ClassifyTrainer, ClsBatch};
use crate::train::pretrain::PretrainOptions;
use crate::util::cli::Args;

fn glue_batches(task: GlueTask, seq: usize, feat: usize, seed: u64, n: usize, batch: usize) -> Vec<ClsBatch> {
    let mut g = GlueGen::new(task, seq, feat, seed);
    (0..n)
        .map(|_| {
            let b = g.batch(batch);
            ClsBatch {
                features: b.features,
                labels: b.labels,
            }
        })
        .collect()
}

fn glue_eval_batches(task: GlueTask, seq: usize, feat: usize, seed: u64, n: usize, batch: usize) -> Vec<ClsBatch> {
    GlueGen::eval_set(task, seq, feat, seed, n, batch)
        .into_iter()
        .map(|b| ClsBatch {
            features: b.features,
            labels: b.labels,
        })
        .collect()
}

/// Table 1: fine-tune the GLUE twin from a dense checkpoint under
/// (sparsity, block) grids; report per-task metrics + average score.
pub fn tab1(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let quick = args.get_bool("quick");
    let pre_steps = args.get_usize("pre-steps", if quick { 25 } else { 50 });
    let ft_steps = args.get_usize("steps", if quick { 25 } else { 50 });
    let sparsities = args.get_f64_list("sparsities", if quick { &[0.9] } else { &[0.7, 0.8, 0.9, 0.95] });
    let mults = args.get_usize_list("mults", if quick { &[1] } else { &[1, 2, 4] }); // b = 32, 64, 128
    let cfg = rt.manifest().config("glue-sim")?.clone();
    let (seq, feat, batch) = (cfg.seq - 1, cfg.patch_dim, cfg.batch);
    let eval_n = args.get_usize("eval-batches", 8);
    let seed: u64 = 0x61e5;

    let mut table = Table::new(
        "Tab.1 — GLUE-sim fine-tuning (paper: robust to s and b; dense avg 66.13)",
        &["config", "CoLA(mcc)", "SST-2(acc)", "MRPC(acc/f1)", "RTE(acc)", "WNLI(acc)", "Avg"],
    );

    // run one (s, b) config across all five tasks
    let mut run_grid = |smax: f64, mult: usize, tag: &str, table: &mut Table| -> Result<()> {
        let mut cells: Vec<String> = vec![tag.to_string()];
        let mut avg = 0.0;
        for task in GlueTask::all() {
            let tseed = seed ^ task.name().len() as u64 * 7919;
            // 1. dense "pretrained" checkpoint on the task
            let dense_opts = PretrainOptions {
                total_iters: pre_steps,
                s_max: 0.0,
                step_size: 5,
                seed: tseed,
                ..Default::default()
            };
            let mut dense = ClassifyTrainer::new(&rt, "glue-sim", &dense_opts)?;
            let train = glue_batches(task, seq, feat, tseed, pre_steps + ft_steps, batch);
            for (i, b) in train.iter().take(pre_steps).enumerate() {
                dense.train_iteration(i, b)?;
            }
            let ckpt = dense.params().clone();
            // 2. sparsify + recover (or keep training dense for tag=dense)
            let ft_opts = PretrainOptions {
                total_iters: ft_steps,
                s_max: smax,
                step_size: 5,
                seed: tseed,
                block_mult: mult,
                ..Default::default()
            };
            let mut ft = ClassifyTrainer::with_params(&rt, "glue-sim", &ft_opts, ckpt)?;
            for (i, b) in train.iter().skip(pre_steps).enumerate() {
                ft.train_iteration(i, b)?;
            }
            let scores = ft.eval(&glue_eval_batches(task, seq, feat, tseed, eval_n, batch))?;
            let (cell, score) = match task {
                GlueTask::CoLA => (format!("{:.1}", scores.matthews * 100.0), scores.matthews * 100.0),
                GlueTask::Mrpc => (
                    format!("{:.1}/{:.1}", scores.accuracy * 100.0, scores.f1 * 100.0),
                    (scores.accuracy + scores.f1) / 2.0 * 100.0,
                ),
                _ => (format!("{:.1}", scores.accuracy * 100.0), scores.accuracy * 100.0),
            };
            cells.push(cell);
            avg += score / 5.0;
        }
        cells.push(format!("{avg:.1}"));
        table.row(&cells);
        Ok(())
    };

    run_grid(0.0, 1, "Dense", &mut table)?;
    for &mult in &mults {
        for &s in &sparsities {
            run_grid(s, mult, &format!("{:.0}%/{}x{}", s * 100.0, 32 * mult, 32 * mult), &mut table)?;
        }
    }
    table.print();
    Ok(())
}

/// Table 3: ViT twin accuracy at increasing sparsity.
pub fn tab3(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let quick = args.get_bool("quick");
    let steps = args.get_usize("steps", if quick { 60 } else { 120 });
    let sparsities = args.get_f64_list("sparsities", &[0.7, 0.8, 0.9, 0.95]);
    let cfg = rt.manifest().config("vit-sim")?.clone();
    let eval_n = args.get_usize("eval-batches", 8);
    let noise = args.get_f64("noise", 1.2) as f32;

    let mut table = Table::new(
        "Tab.3 — ViT-sim accuracy vs sparsity (paper: few-point drop from dense)",
        &["config", "accuracy", "final sparsity"],
    );
    for smax in std::iter::once(0.0).chain(sparsities.iter().copied()) {
        let opts = PretrainOptions {
            total_iters: steps,
            s_max: smax,
            step_size: 5,
            seed: 0x517,
            ..Default::default()
        };
        let mut t = ClassifyTrainer::new(&rt, "vit-sim", &opts)?;
        let mut gen = CifarSim::new(0x517, noise);
        for i in 0..steps {
            let b = gen.batch(cfg.batch);
            t.train_iteration(
                i,
                &ClsBatch {
                    features: b.patches,
                    labels: b.labels,
                },
            )?;
        }
        let eval: Vec<ClsBatch> = CifarSim::eval_set(0x517, noise, eval_n, cfg.batch)
            .into_iter()
            .map(|b| ClsBatch {
                features: b.patches,
                labels: b.labels,
            })
            .collect();
        let scores = t.eval(&eval)?;
        let tag = if smax == 0.0 {
            "Dense".to_string()
        } else {
            format!("BLaST-{:.0}%", smax * 100.0)
        };
        table.row(&[
            tag,
            format!("{:.1}%", scores.accuracy * 100.0),
            format!("{:.2}", t.mean_sparsity()),
        ]);
    }
    table.print();
    Ok(())
}

/// Fig. 9: ViT accuracy vs cumulative training FLOPs under the schedule.
pub fn fig9(args: &Args) -> Result<()> {
    let rt = Runtime::open_default()?;
    let steps = args.get_usize("steps", 120);
    let epoch = args.get_usize("epoch", 20);
    let cfg = rt.manifest().config("vit-sim")?.clone();
    let noise = args.get_f64("noise", 1.2) as f32;
    let eval_n = 6;

    let native = NativeConfig {
        name: cfg.name.clone(),
        kind: ModelKind::Vit,
        vocab: cfg.num_classes,
        emb: cfg.emb,
        ffn: cfg.ffn,
        layers: cfg.layers,
        heads: cfg.heads,
        max_seq: cfg.seq,
        block: cfg.block,
    };
    let tokens_per_iter = (cfg.batch * cfg.seq) as f64;

    let mut table = Table::new(
        "Fig.9 — ViT accuracy vs cumulative PFLOP (paper: BLaST better acc/FLOP)",
        &["iter", "dense acc", "dense GFLOP", "BLaST acc", "BLaST GFLOP"],
    );
    let eval: Vec<ClsBatch> = CifarSim::eval_set(0x519, noise, eval_n, cfg.batch)
        .into_iter()
        .map(|b| ClsBatch {
            features: b.patches,
            labels: b.labels,
        })
        .collect();

    let mut run = |smax: f64| -> Result<Vec<(usize, f64, f64)>> {
        let opts = PretrainOptions {
            total_iters: steps,
            s_max: smax,
            step_size: 5,
            seed: 0x519,
            ..Default::default()
        };
        let sched = SparsitySchedule::new(0.0, smax.max(1e-9), steps, 0);
        let mut t = ClassifyTrainer::new(&rt, "vit-sim", &opts)?;
        let mut gen = CifarSim::new(0x519, noise);
        let mut out = Vec::new();
        for i in 0..steps {
            let b = gen.batch(cfg.batch);
            t.train_iteration(
                i,
                &ClsBatch {
                    features: b.patches,
                    labels: b.labels,
                },
            )?;
            if (i + 1) % epoch == 0 {
                let acc = t.eval(&eval)?.accuracy;
                let fl = flops::cumulative_train_flops(&native, cfg.seq, tokens_per_iter, &sched, i + 1);
                out.push((i + 1, acc, fl / 1e9));
            }
        }
        Ok(out)
    };

    let dense = run(0.0)?;
    let blast = run(0.9)?;
    for (d, b) in dense.iter().zip(&blast) {
        table.row(&[
            d.0.to_string(),
            format!("{:.1}%", d.1 * 100.0),
            format!("{:.1}", d.2),
            format!("{:.1}%", b.1 * 100.0),
            format!("{:.1}", b.2),
        ]);
    }
    table.print();
    println!("\npaper shape: BLaST reaches comparable accuracy with fewer cumulative FLOPs.");
    Ok(())
}
