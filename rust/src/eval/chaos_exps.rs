//! Chaos experiment driver (`blast exp chaos`) — the fault-injection
//! acceptance sweep from the robustness milestone.
//!
//! Serves the same synthetic request load through the coordinator under a
//! matrix of seeded fault plans (round panics, transient decode errors,
//! prefill failures, injected pool exhaustion, decode stalls + deadlines,
//! and a scheduler kill for the watchdog) and checks the liveness
//! invariants after every run:
//!
//! 1. **exactly one** completion per submitted request id — success or
//!    error, never a duplicate, never a drop;
//! 2. no deadlock — the drain loop finishes within its timeout;
//! 3. KV page accounting returns to zero once every session retired.
//!
//! Everything is deterministic: the fault plans' RNG streams are forked
//! from `--seed`, so a failing row reproduces bit-for-bit.
//!
//! With `--replicas N` (N > 1) the sweep appends a **fleet storm** matrix:
//! the same load served through the replicated fleet tier under the
//! replica-level sites (`replica_crash`, `replica_stall_ms`,
//! `heartbeat_drop`), checking the same three invariants plus one more —
//! every KV pool of every replica *incarnation* (including the ones that
//! were deposed and restarted mid-run) drains back to zero pages.

use std::collections::BTreeMap;
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::{
    BatcherConfig, CompletionWait, Coordinator, Fleet, FleetConfig, Request,
};
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::engine::{AttnOptions, Engine, MlpMode};
use crate::model::kv::KvOptions;
use crate::model::params::ParamStore;
use crate::sparse::BlockMask;
use crate::tensor::Tensor;
use crate::train::pretrain::{PretrainOptions, Trainer};
use crate::train::GuardConfig;
use crate::util::cli::Args;
use crate::util::faults::{FaultSite, Faults};
use crate::util::rng::Rng;

fn chaos_config() -> NativeConfig {
    NativeConfig {
        name: "chaos".into(),
        kind: ModelKind::Llama,
        vocab: 64,
        emb: 32,
        ffn: 64,
        layers: 2,
        heads: 4,
        max_seq: 64,
        block: 8,
    }
}

fn chaos_params(cfg: &NativeConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut s = ParamStore::new();
    let e = cfg.emb;
    s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
    for i in 0..cfg.layers {
        let p = |n: &str| format!("layer{i}.{n}");
        s.insert(p("ln1"), Tensor::full(&[e], 1.0));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
        }
        s.insert(p("ln2"), Tensor::full(&[e], 1.0));
        for (n, r, c) in cfg.mlp_shapes() {
            s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
        }
    }
    s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
    s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
    s
}

fn chaos_masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for i in 0..cfg.layers {
        for (n, r, c) in cfg.mlp_shapes() {
            m.insert(
                format!("layer{i}.{n}"),
                BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
            );
        }
    }
    m
}

struct RunReport {
    ok: usize,
    errored: usize,
    disconnected: bool,
    pool_leak: usize,
    metrics: String,
    fault_summary: String,
    health: String,
}

/// One chaos run: serve `n` requests under `faults`, enforce the
/// invariants, and report what happened.
fn run_one(
    faults: Faults,
    n: usize,
    deadline_ms: Option<u64>,
    attn: AttnOptions,
) -> Result<RunReport> {
    let cfg = chaos_config();
    let engine = Arc::new(Engine::new_with_opts(
        cfg.clone(),
        &chaos_params(&cfg, 1),
        &chaos_masks(&cfg, 0.5, 2),
        MlpMode::Sparse,
        // bounded pool: admission gating and retirement accounting are on
        KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true },
        attn,
    )?);
    let engine_stats = engine.clone();
    let pool = engine.kv_pool().clone();
    let mut coord = Coordinator::start_with_faults(
        engine,
        BatcherConfig {
            max_batch: 3,
            max_queue: 64,
            ..BatcherConfig::default()
        },
        faults,
    );
    let mut submitted = 0usize;
    for i in 0..n as u64 {
        let r = coord.submit(Request {
            id: i,
            prompt: (0..2 + (i as usize % 5)).map(|j| ((i as usize * 7 + j * 3) % 64) as u32).collect(),
            max_new: 1 + (i as usize % 6),
            eos: None,
            deadline_ms,
        });
        match r {
            Ok(()) => submitted += 1,
            // the scheduler already died (watchdog ran, channel closed) —
            // the remaining requests were never accepted, stop submitting
            Err(_) => break,
        }
    }
    let mut seen = HashSet::new();
    let (mut ok, mut errored) = (0usize, 0usize);
    let mut disconnected = false;
    while seen.len() < submitted {
        match coord.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                if !seen.insert(c.id) {
                    bail!("invariant violated: duplicate completion for request {}", c.id);
                }
                if c.error.is_some() {
                    errored += 1;
                } else {
                    ok += 1;
                }
            }
            // watchdog path: the scheduler died, every pending request was
            // answered with an error and the channel closed — count what
            // already arrived and stop waiting
            CompletionWait::Disconnected => {
                disconnected = true;
                break;
            }
            CompletionWait::TimedOut => {
                bail!(
                    "invariant violated: deadlock — {}/{submitted} completions after 30s",
                    seen.len()
                );
            }
        }
    }
    let report = RunReport {
        ok,
        errored,
        disconnected,
        pool_leak: 0,
        metrics: coord.metrics_summary(),
        fault_summary: coord.faults().summary(),
        health: format!("{:?}", coord.health()),
    };
    coord.stop();
    // after stop() every session has retired: the page pool must be empty
    let leak = pool.pages_in_use();
    if leak != 0 {
        bail!("invariant violated: {leak} KV pages still held after drain");
    }
    // skip counters stay internally consistent under chaos: a threshold
    // can never skip more than it visited, and an exact engine never
    // counts at all
    let st = engine_stats.attn_stats();
    if st.rows_skipped > st.rows || st.tiles_skipped > st.tiles || st.pages_skipped > st.pages {
        bail!("invariant violated: attention skip counters exceed visits: {st:?}");
    }
    if engine_stats.attn_threshold().is_none() && st.engaged() {
        bail!("invariant violated: exact engine moved skip counters: {st:?}");
    }
    if !disconnected && seen.len() != submitted {
        bail!(
            "invariant violated: {}/{submitted} accepted requests answered",
            seen.len()
        );
    }
    Ok(RunReport { pool_leak: leak, ..report })
}

struct FleetReport {
    ok: usize,
    errored: usize,
    pool_leak: usize,
    metrics: String,
    statuses: String,
}

/// One fleet storm run: serve `n` requests (a shared-prefix mix, so
/// failover replays also exercise the CoW prefix cache) through a
/// `replicas`-wide fleet under `faults`, then enforce the chaos invariants
/// across **every replica incarnation** — including pools owned by replicas
/// that were deposed and restarted mid-run.
fn run_fleet_storm(
    faults: Faults,
    n: usize,
    replicas: usize,
    stall_ms: u64,
    attn: AttnOptions,
) -> Result<FleetReport> {
    let cfg = chaos_config();
    let engine = Engine::new_with_opts(
        cfg.clone(),
        &chaos_params(&cfg, 1),
        &chaos_masks(&cfg, 0.5, 2),
        MlpMode::Sparse,
        KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true },
        attn,
    )?;
    let mut fleet = Fleet::start_with_faults(
        &engine,
        FleetConfig {
            replicas,
            batcher: BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
            seed: 7,
            stall_ms,
            ..FleetConfig::default()
        },
        faults,
    );
    for i in 0..n as u64 {
        // every third request reuses one 4-token prefix
        let mut prompt: Vec<u32> = if i % 3 == 0 { vec![5, 9, 13, 17] } else { Vec::new() };
        prompt.extend((0..2 + (i as usize % 5)).map(|j| ((i as usize * 7 + j * 3) % 64) as u32));
        fleet.submit(Request {
            id: i,
            prompt,
            max_new: 1 + (i as usize % 6),
            eos: None,
            deadline_ms: None,
        })?;
    }
    let mut seen = HashSet::new();
    let (mut ok, mut errored) = (0usize, 0usize);
    while seen.len() < n {
        match fleet.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                if !seen.insert(c.id) {
                    bail!("invariant violated: duplicate completion for request {}", c.id);
                }
                if c.error.is_some() {
                    errored += 1;
                } else {
                    ok += 1;
                }
            }
            CompletionWait::Disconnected => {
                bail!(
                    "invariant violated: fleet router died with {}/{n} completions",
                    seen.len()
                );
            }
            CompletionWait::TimedOut => {
                bail!(
                    "invariant violated: deadlock — {}/{n} fleet completions after 30s",
                    seen.len()
                );
            }
        }
    }
    let metrics = fleet.metrics_summary();
    let statuses = format!("{:?}", fleet.statuses());
    // aggregated skip counters stay consistent across incarnations
    if let Some(st) = fleet.attn_aggregate() {
        if st.rows_skipped > st.rows || st.tiles_skipped > st.tiles || st.pages_skipped > st.pages
        {
            bail!("invariant violated: fleet attention skip counters exceed visits: {st:?}");
        }
    }
    let pools = fleet.pools();
    fleet.stop();
    // after stop() every session on every incarnation has retired
    let leak: usize = pools.iter().map(|p| p.pages_in_use()).sum();
    if leak != 0 {
        bail!(
            "invariant violated: {leak} KV pages still held across {} replica pools after drain",
            pools.len()
        );
    }
    Ok(FleetReport { ok, errored, pool_leak: leak, metrics, statuses })
}

fn train_opts(iters: usize, seed: u64) -> PretrainOptions {
    PretrainOptions {
        total_iters: iters,
        s_max: 0.5,
        step_size: 5,
        seed,
        ..Default::default()
    }
}

/// One guarded training storm on the micro twin: arm `spec` + `gcfg`,
/// run `iters` iterations (autosaving into `ckpt_dir` when given, which
/// also pins a rollback anchor), and hand back the trainer + injector +
/// run outcome for invariant checks.
fn run_train_storm(
    spec: &str,
    gcfg: GuardConfig,
    iters: usize,
    seed: u64,
    ckpt_dir: Option<&Path>,
) -> Result<(Trainer<'static>, Faults, Result<()>)> {
    let faults = if spec.is_empty() { Faults::disabled() } else { Faults::parse(spec)? };
    let mut t = Trainer::new_native("micro", train_opts(iters, seed))?;
    t.set_faults(faults.clone());
    t.arm_guard(gcfg);
    let run = match ckpt_dir {
        Some(dir) => t.run_with_autosave(iters, dir, 4, 8, &faults),
        None => t.run(iters),
    };
    Ok((t, faults, run))
}

fn finite_params(t: &Trainer) -> bool {
    t.params().in_order().all(|(_, w)| w.data().iter().all(|v| v.is_finite()))
}

fn final_loss(t: &Trainer) -> f32 {
    t.log.last().map(|l| l.loss).unwrap_or(f32::NAN)
}

/// Scratch checkpoint directory for one storm; pid-scoped so concurrent
/// CI shards never collide.
fn storm_dir(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("blast-chaos-train-{tag}-{seed}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `blast exp chaos --train [--steps N --seed S --quick]` — the guarded
/// pretraining storm matrix. Each storm arms one (or all) of the four
/// training fault sites against the self-healing ladder on the micro twin
/// and checks the recovery invariants:
///
/// 1. a quiet (permissive) guard is **bit-identical** to guards-off;
/// 2. every armed storm finishes with finite loss + parameters, and every
///    anomaly fire is answered by a recorded skip/clip/revert;
/// 3. the rollback anchor checkpoint stays loadable (CRC quick-verify);
/// 4. exhausted budgets fail loudly (the escalation storm *expects* the
///    run to abort with exact skip/rollback/data-fork counts).
pub fn chaos_train(args: &Args) -> Result<()> {
    let iters = args.get_usize("steps", if args.get_bool("quick") { 10 } else { 24 });
    let seed = args.get_usize("seed", 1) as u64;
    println!("chaos training storms: micro twin, {iters} iters/run, seed {seed}\n");

    // [quiet guard] permissive thresholds must not perturb a single bit
    let mut plain = Trainer::new_native("micro", train_opts(iters, seed))?;
    plain.run(iters)?;
    let (quiet, _, run) = run_train_storm("", GuardConfig::permissive(), iters, seed, None)?;
    run?;
    let identical = plain.log.len() == quiet.log.len()
        && plain
            .log
            .iter()
            .zip(quiet.log.iter())
            .all(|(a, b)| a.loss.to_bits() == b.loss.to_bits());
    if !identical {
        bail!("invariant violated: a permissive guard changed the loss stream");
    }
    let s = quiet.guard().expect("guard armed").stats();
    if s.skips + s.clips + s.rollbacks + s.mask_reverts != 0 {
        bail!("invariant violated: permissive guard intervened: {:?}", s);
    }
    println!("[quiet guard] {iters} iters bit-identical to guards-off");

    // [single-site storms] every fire must be answered by a skip
    let storms: Vec<(&str, String)> = vec![
        ("grad nan storm", format!("grad_nan:0.25:{seed}")),
        ("grad explode storm", format!("grad_explode:0.2:{}:1000000", seed + 1)),
    ];
    for (label, spec) in &storms {
        let (t, f, run) = run_train_storm(spec, GuardConfig::default(), iters, seed, None)?;
        run?;
        let s = t.guard().expect("guard armed").stats();
        let fired: u64 = FaultSite::ALL.iter().map(|&site| f.fired(site)).sum();
        if s.skips < fired {
            bail!(
                "invariant violated: [{label}] {} fires but only {} skips",
                fired,
                s.skips
            );
        }
        if !final_loss(&t).is_finite() || !finite_params(&t) {
            bail!("invariant violated: [{label}] non-finite loss or params survived the guard");
        }
        println!("[{label}] guard: {}", t.guard().unwrap().summary());
        println!("  faults: {}\n", f.summary());
    }

    // [loss spike storm] armed only after one clean iteration: a spike
    // landing before the EWMA baseline exists would be *accepted* (by
    // design — there is nothing to compare against) and poison the
    // baseline; past iteration 0 every fire must be skipped
    {
        let spec = format!("loss_spike_mul:0.3:{}:100", seed + 2);
        let mut t = Trainer::new_native("micro", train_opts(iters, seed))?;
        t.arm_guard(GuardConfig::default());
        t.run(1)?;
        let f = Faults::parse(&spec)?;
        t.set_faults(f.clone());
        t.run(iters - 1)?;
        let s = t.guard().expect("guard armed").stats();
        let fired = f.fired(FaultSite::LossSpikeMul);
        if s.skips < fired {
            bail!(
                "invariant violated: [loss spike storm] {} fires but only {} skips",
                fired,
                s.skips
            );
        }
        if !final_loss(&t).is_finite() || !finite_params(&t) {
            bail!("invariant violated: [loss spike storm] non-finite state");
        }
        println!("[loss spike storm] guard: {}", t.guard().unwrap().summary());
        println!("  faults: {}\n", f.summary());
    }

    // [mask corrupt storm] every update is corrupted; under a paranoid
    // budget (the probe passes only if the update *halves* the loss —
    // impossible) every probed update must revert deterministically, so
    // the corruption never reaches the masks
    {
        let spec = format!("mask_corrupt:1:{}", seed + 3);
        let gcfg = GuardConfig { mask_budget: -0.5, ..GuardConfig::default() };
        let (t, f, run) = run_train_storm(&spec, gcfg, iters, seed, None)?;
        run?;
        let s = t.guard().expect("guard armed").stats();
        if s.mask_reverts < 1 || s.mask_updates_deferred < 1 {
            bail!(
                "invariant violated: [mask corrupt storm] reverts {} deferred {} (want >=1 each)",
                s.mask_reverts,
                s.mask_updates_deferred
            );
        }
        if t.controller().mean_sparsity() != 0.0 {
            bail!(
                "invariant violated: [mask corrupt storm] corruption reached the masks \
                 (sparsity {:.3})",
                t.controller().mean_sparsity()
            );
        }
        if !final_loss(&t).is_finite() || !finite_params(&t) {
            bail!("invariant violated: [mask corrupt storm] non-finite state");
        }
        println!("[mask corrupt storm] guard: {}", t.guard().unwrap().summary());
        println!("  faults: {}\n", f.summary());
    }

    // [everything at once] all four sites against loosened budgets, with
    // autosaves pinning a rollback anchor that must stay loadable
    {
        let dir = storm_dir("all", seed);
        let spec = format!(
            "grad_nan:0.1:{s},grad_explode:0.1:{s}:1000000,\
             loss_spike_mul:0.15:{s}:100,mask_corrupt:0.5:{s}",
            s = seed + 4
        );
        let gcfg = GuardConfig {
            max_skips: 12,
            max_rollbacks: 50,
            mask_budget: 0.1,
            // a persistent-corruption regime is flat, not rising: loosen
            // the divergence trigger so the storm cannot ping-pong the
            // rollback budget
            div_tol: 0.5,
            ..GuardConfig::default()
        };
        let (t, f, run) = run_train_storm(&spec, gcfg, iters, seed, Some(&dir))?;
        run?;
        if !final_loss(&t).is_finite() || !finite_params(&t) {
            bail!("invariant violated: [everything at once] non-finite state");
        }
        let anchor = t
            .rollback_anchor()
            .ok_or_else(|| anyhow::anyhow!("no rollback anchor was pinned"))?;
        ParamStore::quick_verify(anchor)?;
        println!("[everything at once] guard: {}", t.guard().unwrap().summary());
        println!("  anchor {} quick-verified, faults: {}\n", anchor.display(), f.summary());
        let _ = std::fs::remove_dir_all(&dir);
    }

    // [skip escalation] grad_nan at probability 1 never draws the RNG, so
    // the trajectory is exact regardless of seed: 2 skips per lap, three
    // anchored rollbacks (each re-forking the data order), then the
    // budget-exhaustion abort on the fourth escalation
    {
        let dir = storm_dir("esc", seed);
        let spec = format!("grad_nan:1:{}", seed + 5);
        let gcfg = GuardConfig { max_skips: 2, max_rollbacks: 3, ..GuardConfig::default() };
        let (t, _f, run) = run_train_storm(&spec, gcfg, iters, seed, Some(&dir))?;
        let err = match run {
            Ok(()) => bail!("invariant violated: rollback budget never exhausted"),
            Err(e) => format!("{e:#}"),
        };
        if !err.contains("rollback budget") {
            bail!("invariant violated: unexpected escalation failure: {err}");
        }
        let s = t.guard().expect("guard armed").stats();
        if s.rollbacks != 3 || s.skips != 8 || t.data_fork() != 3 {
            bail!(
                "invariant violated: escalation trajectory off: rollbacks {} skips {} forks {}",
                s.rollbacks,
                s.skips,
                t.data_fork()
            );
        }
        println!("[skip escalation] aborted as designed after 3 rollbacks / 8 skips");
        println!("  error: {err}\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!(
        "all training storm invariants held: quiet guard bit-identical, anomalies answered, \
         anchors verifiable, budgets fail loudly"
    );
    Ok(())
}

/// `blast exp chaos [--requests N --seed S --deadline-ms D --replicas R
/// --attn-threshold TAU | --train --steps N]`.
pub fn chaos(args: &Args) -> Result<()> {
    // `--train` selects the guarded-pretraining storm matrix instead of
    // the serving sweep
    if args.get_bool("train") {
        return chaos_train(args);
    }
    let n = args.get_usize("requests", if args.get_bool("quick") { 8 } else { 24 });
    let seed = args.get_usize("seed", 1) as u64;
    let deadline = args.get_usize("deadline-ms", 2_000) as u64;
    // `--attn-threshold TAU` arms BLASST dynamic attention sparsity on
    // every chaos engine — the storms then also prove the skip counters
    // stay consistent (skipped <= visited) under faults
    let attn = AttnOptions { threshold: args.get_threshold("attn-threshold") };
    let plans: Vec<(&str, String)> = vec![
        ("baseline", String::new()),
        ("round panic", format!("decode_round_panic:0.15:{seed}")),
        ("transient error (retried)", format!("decode_round_error:0.2:{}", seed + 1)),
        ("prefill error", format!("prefill_error:0.25:{}", seed + 2)),
        ("pool exhausted", format!("kv_pool_exhausted:0.15:{}", seed + 3)),
        ("stall + deadline", format!("decode_stall_ms:0.5:{}:40", seed + 4)),
        (
            "everything at once",
            format!(
                "decode_round_panic:0.05:{s}:0,decode_round_error:0.1:{s},\
                 prefill_error:0.1:{s},kv_pool_exhausted:0.05:{s},decode_stall_ms:0.2:{s}:10",
                s = seed + 5
            ),
        ),
        ("scheduler kill (watchdog)", format!("scheduler_panic:1:{}", seed + 6)),
    ];
    println!(
        "chaos sweep: {n} requests/run, seed {seed}, deadline {deadline}ms on stall runs\n"
    );
    if let Some(tau) = attn.threshold {
        println!("attn threshold armed: tau={tau}\n");
    }
    for (label, spec) in &plans {
        let faults = if spec.is_empty() { Faults::disabled() } else { Faults::parse(spec)? };
        let deadline_ms = if spec.contains("stall") { Some(deadline) } else { None };
        let r = run_one(faults, n, deadline_ms, attn)?;
        println!(
            "[{label}] ok {} / errored {}{}  health {}  pool leak {}",
            r.ok,
            r.errored,
            if r.disconnected { " (worker died, watchdog drained)" } else { "" },
            r.health,
            r.pool_leak
        );
        println!("  {}", r.metrics);
        println!("  faults: {}\n", r.fault_summary);
    }
    println!("all chaos invariants held: one completion per request, no deadlock, pool drained");
    // `--replicas N` appends the fleet storm matrix: the replica-level
    // sites against the replicated tier, same invariants + per-incarnation
    // pool drain
    let replicas = args.get_usize("replicas", 1);
    if replicas > 1 {
        let storms: Vec<(&str, String)> = vec![
            ("fleet baseline", String::new()),
            ("replica crash storm", format!("replica_crash:0.05:{}", seed + 7)),
            (
                "replica kill storm (all sites)",
                format!(
                    "replica_crash:0.03:{s},replica_stall_ms:0.04:{s}:60,heartbeat_drop:0.3:{s}",
                    s = seed + 8
                ),
            ),
        ];
        println!("fleet storm matrix: {replicas} replicas, {n} requests/run\n");
        for (label, spec) in &storms {
            let faults = if spec.is_empty() { Faults::disabled() } else { Faults::parse(spec)? };
            // armed runs tighten the stall detector so injected 60ms
            // freezes are actually deposed
            let stall_ms = if spec.is_empty() { 250 } else { 40 };
            let r = run_fleet_storm(faults, n, replicas, stall_ms, attn)?;
            println!(
                "[{label}] ok {} / errored {}  pool leak {}",
                r.ok, r.errored, r.pool_leak
            );
            println!("  {}", r.metrics);
            println!("  statuses: {}\n", r.statuses);
        }
        println!(
            "all fleet storm invariants held: exactly-once completion, no deadlock, \
             every incarnation's pool drained"
        );
    }
    Ok(())
}
