//! Chaos experiment driver (`blast exp chaos`) — the fault-injection
//! acceptance sweep from the robustness milestone.
//!
//! Serves the same synthetic request load through the coordinator under a
//! matrix of seeded fault plans (round panics, transient decode errors,
//! prefill failures, injected pool exhaustion, decode stalls + deadlines,
//! and a scheduler kill for the watchdog) and checks the liveness
//! invariants after every run:
//!
//! 1. **exactly one** completion per submitted request id — success or
//!    error, never a duplicate, never a drop;
//! 2. no deadlock — the drain loop finishes within its timeout;
//! 3. KV page accounting returns to zero once every session retired.
//!
//! Everything is deterministic: the fault plans' RNG streams are forked
//! from `--seed`, so a failing row reproduces bit-for-bit.
//!
//! With `--replicas N` (N > 1) the sweep appends a **fleet storm** matrix:
//! the same load served through the replicated fleet tier under the
//! replica-level sites (`replica_crash`, `replica_stall_ms`,
//! `heartbeat_drop`), checking the same three invariants plus one more —
//! every KV pool of every replica *incarnation* (including the ones that
//! were deposed and restarted mid-run) drains back to zero pages.

use std::collections::BTreeMap;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::{
    BatcherConfig, CompletionWait, Coordinator, Fleet, FleetConfig, Request,
};
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::engine::{AttnOptions, Engine, MlpMode};
use crate::model::kv::KvOptions;
use crate::model::params::ParamStore;
use crate::sparse::BlockMask;
use crate::tensor::Tensor;
use crate::util::cli::Args;
use crate::util::faults::Faults;
use crate::util::rng::Rng;

fn chaos_config() -> NativeConfig {
    NativeConfig {
        name: "chaos".into(),
        kind: ModelKind::Llama,
        vocab: 64,
        emb: 32,
        ffn: 64,
        layers: 2,
        heads: 4,
        max_seq: 64,
        block: 8,
    }
}

fn chaos_params(cfg: &NativeConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut s = ParamStore::new();
    let e = cfg.emb;
    s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
    for i in 0..cfg.layers {
        let p = |n: &str| format!("layer{i}.{n}");
        s.insert(p("ln1"), Tensor::full(&[e], 1.0));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
        }
        s.insert(p("ln2"), Tensor::full(&[e], 1.0));
        for (n, r, c) in cfg.mlp_shapes() {
            s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
        }
    }
    s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
    s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
    s
}

fn chaos_masks(cfg: &NativeConfig, sparsity: f64, seed: u64) -> BTreeMap<String, BlockMask> {
    let mut rng = Rng::new(seed);
    let mut m = BTreeMap::new();
    for i in 0..cfg.layers {
        for (n, r, c) in cfg.mlp_shapes() {
            m.insert(
                format!("layer{i}.{n}"),
                BlockMask::random(r / cfg.block, c / cfg.block, sparsity, &mut rng),
            );
        }
    }
    m
}

struct RunReport {
    ok: usize,
    errored: usize,
    disconnected: bool,
    pool_leak: usize,
    metrics: String,
    fault_summary: String,
    health: String,
}

/// One chaos run: serve `n` requests under `faults`, enforce the
/// invariants, and report what happened.
fn run_one(
    faults: Faults,
    n: usize,
    deadline_ms: Option<u64>,
    attn: AttnOptions,
) -> Result<RunReport> {
    let cfg = chaos_config();
    let engine = Arc::new(Engine::new_with_opts(
        cfg.clone(),
        &chaos_params(&cfg, 1),
        &chaos_masks(&cfg, 0.5, 2),
        MlpMode::Sparse,
        // bounded pool: admission gating and retirement accounting are on
        KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true },
        attn,
    )?);
    let engine_stats = engine.clone();
    let pool = engine.kv_pool().clone();
    let mut coord = Coordinator::start_with_faults(
        engine,
        BatcherConfig {
            max_batch: 3,
            max_queue: 64,
            ..BatcherConfig::default()
        },
        faults,
    );
    let mut submitted = 0usize;
    for i in 0..n as u64 {
        let r = coord.submit(Request {
            id: i,
            prompt: (0..2 + (i as usize % 5)).map(|j| ((i as usize * 7 + j * 3) % 64) as u32).collect(),
            max_new: 1 + (i as usize % 6),
            eos: None,
            deadline_ms,
        });
        match r {
            Ok(()) => submitted += 1,
            // the scheduler already died (watchdog ran, channel closed) —
            // the remaining requests were never accepted, stop submitting
            Err(_) => break,
        }
    }
    let mut seen = HashSet::new();
    let (mut ok, mut errored) = (0usize, 0usize);
    let mut disconnected = false;
    while seen.len() < submitted {
        match coord.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                if !seen.insert(c.id) {
                    bail!("invariant violated: duplicate completion for request {}", c.id);
                }
                if c.error.is_some() {
                    errored += 1;
                } else {
                    ok += 1;
                }
            }
            // watchdog path: the scheduler died, every pending request was
            // answered with an error and the channel closed — count what
            // already arrived and stop waiting
            CompletionWait::Disconnected => {
                disconnected = true;
                break;
            }
            CompletionWait::TimedOut => {
                bail!(
                    "invariant violated: deadlock — {}/{submitted} completions after 30s",
                    seen.len()
                );
            }
        }
    }
    let report = RunReport {
        ok,
        errored,
        disconnected,
        pool_leak: 0,
        metrics: coord.metrics_summary(),
        fault_summary: coord.faults().summary(),
        health: format!("{:?}", coord.health()),
    };
    coord.stop();
    // after stop() every session has retired: the page pool must be empty
    let leak = pool.pages_in_use();
    if leak != 0 {
        bail!("invariant violated: {leak} KV pages still held after drain");
    }
    // skip counters stay internally consistent under chaos: a threshold
    // can never skip more than it visited, and an exact engine never
    // counts at all
    let st = engine_stats.attn_stats();
    if st.rows_skipped > st.rows || st.tiles_skipped > st.tiles || st.pages_skipped > st.pages {
        bail!("invariant violated: attention skip counters exceed visits: {st:?}");
    }
    if engine_stats.attn_threshold().is_none() && st.engaged() {
        bail!("invariant violated: exact engine moved skip counters: {st:?}");
    }
    if !disconnected && seen.len() != submitted {
        bail!(
            "invariant violated: {}/{submitted} accepted requests answered",
            seen.len()
        );
    }
    Ok(RunReport { pool_leak: leak, ..report })
}

struct FleetReport {
    ok: usize,
    errored: usize,
    pool_leak: usize,
    metrics: String,
    statuses: String,
}

/// One fleet storm run: serve `n` requests (a shared-prefix mix, so
/// failover replays also exercise the CoW prefix cache) through a
/// `replicas`-wide fleet under `faults`, then enforce the chaos invariants
/// across **every replica incarnation** — including pools owned by replicas
/// that were deposed and restarted mid-run.
fn run_fleet_storm(
    faults: Faults,
    n: usize,
    replicas: usize,
    stall_ms: u64,
    attn: AttnOptions,
) -> Result<FleetReport> {
    let cfg = chaos_config();
    let engine = Engine::new_with_opts(
        cfg.clone(),
        &chaos_params(&cfg, 1),
        &chaos_masks(&cfg, 0.5, 2),
        MlpMode::Sparse,
        KvOptions { page: 4, pool_pages: Some(64), prefix_cache: true },
        attn,
    )?;
    let mut fleet = Fleet::start_with_faults(
        &engine,
        FleetConfig {
            replicas,
            batcher: BatcherConfig { max_batch: 3, max_queue: 64, ..BatcherConfig::default() },
            seed: 7,
            stall_ms,
            ..FleetConfig::default()
        },
        faults,
    );
    for i in 0..n as u64 {
        // every third request reuses one 4-token prefix
        let mut prompt: Vec<u32> = if i % 3 == 0 { vec![5, 9, 13, 17] } else { Vec::new() };
        prompt.extend((0..2 + (i as usize % 5)).map(|j| ((i as usize * 7 + j * 3) % 64) as u32));
        fleet.submit(Request {
            id: i,
            prompt,
            max_new: 1 + (i as usize % 6),
            eos: None,
            deadline_ms: None,
        })?;
    }
    let mut seen = HashSet::new();
    let (mut ok, mut errored) = (0usize, 0usize);
    while seen.len() < n {
        match fleet.next_completion(Duration::from_secs(30)) {
            CompletionWait::Ready(c) => {
                if !seen.insert(c.id) {
                    bail!("invariant violated: duplicate completion for request {}", c.id);
                }
                if c.error.is_some() {
                    errored += 1;
                } else {
                    ok += 1;
                }
            }
            CompletionWait::Disconnected => {
                bail!(
                    "invariant violated: fleet router died with {}/{n} completions",
                    seen.len()
                );
            }
            CompletionWait::TimedOut => {
                bail!(
                    "invariant violated: deadlock — {}/{n} fleet completions after 30s",
                    seen.len()
                );
            }
        }
    }
    let metrics = fleet.metrics_summary();
    let statuses = format!("{:?}", fleet.statuses());
    // aggregated skip counters stay consistent across incarnations
    if let Some(st) = fleet.attn_aggregate() {
        if st.rows_skipped > st.rows || st.tiles_skipped > st.tiles || st.pages_skipped > st.pages
        {
            bail!("invariant violated: fleet attention skip counters exceed visits: {st:?}");
        }
    }
    let pools = fleet.pools();
    fleet.stop();
    // after stop() every session on every incarnation has retired
    let leak: usize = pools.iter().map(|p| p.pages_in_use()).sum();
    if leak != 0 {
        bail!(
            "invariant violated: {leak} KV pages still held across {} replica pools after drain",
            pools.len()
        );
    }
    Ok(FleetReport { ok, errored, pool_leak: leak, metrics, statuses })
}

/// `blast exp chaos [--requests N --seed S --deadline-ms D --replicas R
/// --attn-threshold TAU]`.
pub fn chaos(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", if args.get_bool("quick") { 8 } else { 24 });
    let seed = args.get_usize("seed", 1) as u64;
    let deadline = args.get_usize("deadline-ms", 2_000) as u64;
    // `--attn-threshold TAU` arms BLASST dynamic attention sparsity on
    // every chaos engine — the storms then also prove the skip counters
    // stay consistent (skipped <= visited) under faults
    let attn = AttnOptions { threshold: args.get_threshold("attn-threshold") };
    let plans: Vec<(&str, String)> = vec![
        ("baseline", String::new()),
        ("round panic", format!("decode_round_panic:0.15:{seed}")),
        ("transient error (retried)", format!("decode_round_error:0.2:{}", seed + 1)),
        ("prefill error", format!("prefill_error:0.25:{}", seed + 2)),
        ("pool exhausted", format!("kv_pool_exhausted:0.15:{}", seed + 3)),
        ("stall + deadline", format!("decode_stall_ms:0.5:{}:40", seed + 4)),
        (
            "everything at once",
            format!(
                "decode_round_panic:0.05:{s}:0,decode_round_error:0.1:{s},\
                 prefill_error:0.1:{s},kv_pool_exhausted:0.05:{s},decode_stall_ms:0.2:{s}:10",
                s = seed + 5
            ),
        ),
        ("scheduler kill (watchdog)", format!("scheduler_panic:1:{}", seed + 6)),
    ];
    println!(
        "chaos sweep: {n} requests/run, seed {seed}, deadline {deadline}ms on stall runs\n"
    );
    if let Some(tau) = attn.threshold {
        println!("attn threshold armed: tau={tau}\n");
    }
    for (label, spec) in &plans {
        let faults = if spec.is_empty() { Faults::disabled() } else { Faults::parse(spec)? };
        let deadline_ms = if spec.contains("stall") { Some(deadline) } else { None };
        let r = run_one(faults, n, deadline_ms, attn)?;
        println!(
            "[{label}] ok {} / errored {}{}  health {}  pool leak {}",
            r.ok,
            r.errored,
            if r.disconnected { " (worker died, watchdog drained)" } else { "" },
            r.health,
            r.pool_leak
        );
        println!("  {}", r.metrics);
        println!("  faults: {}\n", r.fault_summary);
    }
    println!("all chaos invariants held: one completion per request, no deadlock, pool drained");
    // `--replicas N` appends the fleet storm matrix: the replica-level
    // sites against the replicated tier, same invariants + per-incarnation
    // pool drain
    let replicas = args.get_usize("replicas", 1);
    if replicas > 1 {
        let storms: Vec<(&str, String)> = vec![
            ("fleet baseline", String::new()),
            ("replica crash storm", format!("replica_crash:0.05:{}", seed + 7)),
            (
                "replica kill storm (all sites)",
                format!(
                    "replica_crash:0.03:{s},replica_stall_ms:0.04:{s}:60,heartbeat_drop:0.3:{s}",
                    s = seed + 8
                ),
            ),
        ];
        println!("fleet storm matrix: {replicas} replicas, {n} requests/run\n");
        for (label, spec) in &storms {
            let faults = if spec.is_empty() { Faults::disabled() } else { Faults::parse(spec)? };
            // armed runs tighten the stall detector so injected 60ms
            // freezes are actually deposed
            let stall_ms = if spec.is_empty() { 250 } else { 40 };
            let r = run_fleet_storm(faults, n, replicas, stall_ms, attn)?;
            println!(
                "[{label}] ok {} / errored {}  pool leak {}",
                r.ok, r.errored, r.pool_leak
            );
            println!("  {}", r.metrics);
            println!("  statuses: {}\n", r.statuses);
        }
        println!(
            "all fleet storm invariants held: exactly-once completion, no deadlock, \
             every incarnation's pool drained"
        );
    }
    Ok(())
}
