//! Pretraining experiments: Table 2, Fig. 8, the ablations (Tables 4–6,
//! Figs. 10–11), and the dense-vs-sparse training-step A/B harness
//! (`blast exp pretrain` → `BENCH_pretrain.json`).
//!
//! All drive [`crate::train::Trainer`] over the synthetic corpus; geometry
//! is the `gpt2s-sim` / `llama-sim` scaled twin and iteration counts are
//! scaled with `--steps` (paper: m = 10,000 over 4.9B tokens; default
//! here: 80). The **native** backend executes by default — the full
//! forward + backward + Adam step on the packed kernel stack, so these
//! experiments run in every build; `--backend aot` selects the PJRT
//! executable path (requires the `pjrt` feature + `make artifacts`, and
//! reports exactly that when unavailable).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::data::corpus::Corpus;
use crate::model::config::sim_config;
use crate::model::params::ParamStore;
use crate::runtime::Runtime;
use crate::sparse::BlockMask;
use crate::sparsify::SparsitySchedule;
use crate::testkit::bench::{bench_cfg, fmt_time, JsonReport, Table};
use crate::train::backend::TrainState;
use crate::train::native::{MlpExec, NativeBackend};
use crate::train::pretrain::{PretrainOptions, Trainer};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::time::Duration;

pub fn open_runtime() -> Result<Runtime> {
    Runtime::open_default()
}

/// `--backend native|aot` (native default). Returns the opened runtime for
/// the AOT choice — `None` means run natively — and prints which backend
/// will execute, so default builds never die on a bare missing-`pjrt`
/// error unless the user explicitly asked for the AOT path.
fn open_backend(args: &Args) -> Result<Option<Runtime>> {
    let rt = crate::train::pretrain::open_backend_runtime(&args.get_str("backend", "native"))?;
    match &rt {
        None => println!("backend: native (packed-kernel train step; --backend aot for PJRT)"),
        Some(_) => println!("backend: aot (PJRT executables)"),
    }
    Ok(rt)
}

/// Build a trainer on whichever backend [`open_backend`] selected.
fn new_trainer<'rt>(
    rt: &'rt Option<Runtime>,
    config: &str,
    opts: PretrainOptions,
) -> Result<Trainer<'rt>> {
    Trainer::from_backend(rt.as_ref(), config, opts)
}

fn base_opts(args: &Args) -> PretrainOptions {
    let steps = args.get_usize("steps", 80);
    PretrainOptions {
        total_iters: steps,
        s_init: 0.0,
        s_max: args.get_f64("smax", 0.8),
        decay: args.get_usize("decay", 0),
        step_size: args.get_usize("step-size", 10),
        dense_right: 0,
        dense_left: 0,
        seed: args.get_usize("seed", 0xB1A57) as u64,
        branching: args.get_usize("branching", 8),
        block_mult: 1,
    }
}

/// Run one pretraining configuration; returns (wall secs, perplexity,
/// trainer for further inspection).
fn run_one<'rt>(
    rt: &'rt Option<Runtime>,
    config: &str,
    opts: PretrainOptions,
    eval_batches: usize,
) -> Result<(f64, f64, Trainer<'rt>)> {
    let mut t = new_trainer(rt, config, opts.clone())?;
    let t0 = std::time::Instant::now();
    t.run(opts.total_iters)?;
    let secs = t0.elapsed().as_secs_f64();
    let ppl = t.eval_perplexity(eval_batches)?;
    Ok((secs, ppl, t))
}

/// Table 2: end-to-end pretraining time + perplexity, dense vs BLaST.
pub fn tab2(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let opts = base_opts(args);
    let evals = args.get_usize("eval-batches", 8);
    let mut table = Table::new(
        "Tab.2 — pretraining wall-clock + PPL (paper: BLaST ~10% faster, small PPL gap)",
        &["model", "config", "s_max", "b", "step", "d", "time(s)", "PPL"],
    );
    for config in ["gpt2s-sim", "llama-sim"] {
        // dense baseline
        let dense = PretrainOptions {
            s_max: 0.0,
            ..opts.clone()
        };
        let (secs, ppl, _) = run_one(&rt, config, dense, evals)?;
        table.row(&[
            config.into(),
            "dense".into(),
            "0%".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            format!("{secs:.1}"),
            format!("{ppl:.2}"),
        ]);
        // BLaST: the Table 2 hyper-parameter shapes, scaled
        let d_big = (opts.total_iters as f64 * 0.9) as usize;
        for (smax, mult, step, d, tag) in [
            (0.80, 4, opts.step_size, d_big, "80%/128"),
            (0.75, 4, opts.step_size, d_big, "75%/128"),
            (0.70, 2, opts.step_size, 0, "70%/64"),
        ] {
            let o = PretrainOptions {
                s_max: smax,
                block_mult: mult,
                step_size: step,
                decay: d,
                dense_right: args.get_usize("dense-right", 1),
                ..opts.clone()
            };
            let (secs, ppl, t) = run_one(&rt, config, o, evals)?;
            table.row(&[
                config.into(),
                format!("BLaST-{tag}"),
                format!("{:.0}%", smax * 100.0),
                format!("{}", 32 * mult),
                format!("{step}"),
                format!("{d}"),
                format!("{secs:.1}"),
                format!("{ppl:.2}"),
            ]);
            drop(t);
        }
    }
    table.print();
    Ok(())
}

/// Fig. 8: per-iteration time. With the native backend both series are
/// *measured*: the step now runs the masked MLP through BSpMM once the
/// schedule crosses the runtime switch, so the per-iteration drop is real
/// wall-clock, plus the mask-regeneration spikes. (On `--backend aot` the
/// HLO step computes the masked MLP densely and the sparse series is a
/// projection from native MLP timings, as before — see EXPERIMENTS.md.)
pub fn fig8(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let opts = PretrainOptions {
        dense_right: 1,
        block_mult: 2,
        ..base_opts(args)
    };
    let config = args.get_str("config", "gpt2s-sim");
    let mut t = new_trainer(&rt, &config, opts.clone())?;
    t.run(opts.total_iters)?;
    let cfg = t.config().clone();

    // native MLP projection at this twin's geometry (the aot-backend
    // series; for the native backend it contextualizes the measured step)
    let (tok, emb, ffn) = (cfg.batch * cfg.seq, cfg.emb, cfg.ffn);
    let mut rng = crate::util::rng::Rng::new(8);
    let x = crate::tensor::Tensor::randn(&[tok, emb], 0.5, &mut rng);
    let w1 = crate::tensor::Tensor::randn(&[emb, ffn], 0.02, &mut rng);
    let w3 = crate::tensor::Tensor::randn(&[ffn, emb], 0.02, &mut rng);
    let mut mlp_native = |s: f64| -> f64 {
        let b = cfg.block * opts.block_mult;
        let m1 = crate::sparse::BlockMask::random(emb / b, ffn / b, s, &mut rng.fork(1));
        let m3 = crate::sparse::BlockMask::random(ffn / b, emb / b, s, &mut rng.fork(2));
        let s1 = crate::sparse::Bcsc::from_dense(&w1, &m1, b);
        let s3 = crate::sparse::Bcsc::from_dense(&w3, &m3, b);
        let meas = crate::testkit::bench::bench_quick("mlp", || {
            crate::testkit::bench::black_box(crate::kernels::bspmm::gelu_mlp_sparse(&x, &s1, &s3));
        });
        meas.secs()
    };
    let t_mlp_dense = mlp_native(0.0);

    let mut table = Table::new(
        &format!(
            "Fig.8 — time/iteration, {config} (paper: sparse config drops below dense once BSpMM activates)"
        ),
        &["iter", "s(i)", "step (ms)", "mask upd", "native MLP @s (ms)", "native MLP dense (ms)"],
    );
    let stride = (opts.total_iters / 20).max(1);
    for l in t.log.iter().filter(|l| l.iter % stride == 0) {
        let t_mlp_s = mlp_native(l.mean_mask_sparsity);
        table.row(&[
            l.iter.to_string(),
            format!("{:.2}", l.mean_mask_sparsity),
            format!("{:.1}", l.secs * 1e3),
            if l.mask_update { "*".into() } else { "".into() },
            format!("{:.2}", t_mlp_s * 1e3),
            format!("{:.2}", t_mlp_dense * 1e3),
        ]);
    }
    table.print();
    Ok(())
}

/// Table 4: perplexity vs block size b ∈ {1, 16, 32, 64, 128} @ s=70%.
pub fn tab4(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let mut opts = base_opts(args);
    opts.s_max = 0.7;
    opts.step_size = args.get_usize("step-size", 1); // paper: mask every iter
    let evals = args.get_usize("eval-batches", 8);
    let mut table = Table::new(
        "Tab.4 — PPL vs block size @70% (paper: 1x1 clearly worst, 16..128 similar)",
        &["b", "config", "PPL", "mean regrown ratio"],
    );
    // dense reference
    let (_, ppl_dense, _) = run_one(
        &rt,
        "gpt2s-sim",
        PretrainOptions {
            s_max: 0.0,
            ..opts.clone()
        },
        evals,
    )?;
    table.row(&["dense".into(), "gpt2s-sim".into(), format!("{ppl_dense:.2}"), "-".into()]);
    for (b, config, mult) in [
        (1usize, "gpt2s-sim-b1", 1usize),
        (16, "gpt2s-sim-b16", 1),
        (32, "gpt2s-sim", 1),
        (64, "gpt2s-sim", 2),
        (128, "gpt2s-sim", 4),
    ] {
        let o = PretrainOptions {
            block_mult: mult,
            ..opts.clone()
        };
        let (_, ppl, t) = run_one(&rt, config, o, evals)?;
        let ratios: Vec<f64> = t
            .controller()
            .history()
            .iter()
            .map(|u| u.stats.regrown_ratio)
            .collect();
        table.row(&[
            b.to_string(),
            config.into(),
            format!("{ppl:.2}"),
            format!("{:.3}", crate::util::stats::mean(&ratios)),
        ]);
    }
    table.print();
    Ok(())
}

/// Fig. 10: regrown-block ratio over training for each block size.
pub fn fig10(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let mut opts = base_opts(args);
    opts.s_max = 0.7;
    opts.step_size = 1;
    let mut table = Table::new(
        "Fig.10 — regrown-block ratio vs iteration (paper: b=1 highest & noisiest)",
        &["iter", "b=1", "b=16", "b=32", "b=64", "b=128"],
    );
    let mut series: Vec<Vec<f64>> = Vec::new();
    for (config, mult) in [
        ("gpt2s-sim-b1", 1usize),
        ("gpt2s-sim-b16", 1),
        ("gpt2s-sim", 1),
        ("gpt2s-sim", 2),
        ("gpt2s-sim", 4),
    ] {
        let o = PretrainOptions {
            block_mult: mult,
            ..opts.clone()
        };
        let mut t = new_trainer(&rt, config, o)?;
        t.run(opts.total_iters)?;
        series.push(
            t.controller()
                .history()
                .iter()
                .map(|u| u.stats.regrown_ratio)
                .collect(),
        );
    }
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    let stride = (n / 20).max(1);
    for i in (0..n).step_by(stride) {
        table.row(&[
            i.to_string(),
            format!("{:.3}", series[0][i]),
            format!("{:.3}", series[1][i]),
            format!("{:.3}", series[2][i]),
            format!("{:.3}", series[3][i]),
            format!("{:.3}", series[4][i]),
        ]);
    }
    table.print();
    // paper shape: mean ratio at b=1 exceeds blocked variants
    let means: Vec<f64> = series.iter().map(|s| crate::util::stats::mean(s)).collect();
    println!("\nmean regrown ratios: b=1 {:.3}, b=16 {:.3}, b=32 {:.3}, b=64 {:.3}, b=128 {:.3}",
        means[0], means[1], means[2], means[3], means[4]);
    Ok(())
}

/// Table 5: perplexity vs step_size (paper: flat until 1000).
pub fn tab5(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let mut opts = base_opts(args);
    opts.s_max = 0.7;
    let evals = args.get_usize("eval-batches", 8);
    let steps = opts.total_iters;
    // the paper sweeps 1..1000 over m=10,000; scale the "too large" point
    // to ~2/3 of total iters
    let sweep = [1usize, 2, 5, 10, 25, 50, (steps * 2) / 3];
    let mut table = Table::new(
        "Tab.5 — PPL vs step_size @32x32, 70% (paper: flat until step_size too large)",
        &["step_size", "PPL"],
    );
    for ss in sweep {
        let o = PretrainOptions {
            step_size: ss,
            ..opts.clone()
        };
        let (_, ppl, _) = run_one(&rt, "gpt2s-sim", o, evals)?;
        table.row(&[ss.to_string(), format!("{ppl:.2}")]);
    }
    table.print();
    Ok(())
}

/// Table 6: perplexity vs decay d (paper: negligible effect).
pub fn tab6(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let mut opts = base_opts(args);
    opts.s_max = 0.7;
    let evals = args.get_usize("eval-batches", 8);
    let m = opts.total_iters;
    let mut table = Table::new(
        "Tab.6 — PPL vs sparsity decay d (paper: flat; earlier SpMM activation for free)",
        &["d", "d/m", "60%-sparsity reached at iter", "PPL"],
    );
    for frac in [0.0, 0.1, 0.4, 0.7, 0.9] {
        let d = (m as f64 * frac) as usize;
        let o = PretrainOptions {
            decay: d,
            ..opts.clone()
        };
        let sched = SparsitySchedule::new(0.0, 0.7, m, d.min(m - 1));
        let at60 = sched
            .first_iter_reaching(0.6)
            .map(|i| i.to_string())
            .unwrap_or_else(|| "never".into());
        let (_, ppl, _) = run_one(&rt, "gpt2s-sim", o, evals)?;
        table.row(&[
            d.to_string(),
            format!("{frac:.1}"),
            at60,
            format!("{ppl:.2}"),
        ]);
    }
    table.print();
    Ok(())
}

/// Fig. 11: dense-layer placement — keep L MLP blocks dense on the left vs
/// the right (paper: right placement preserves perplexity better).
pub fn fig11(args: &Args) -> Result<()> {
    let rt = open_backend(args)?;
    let mut opts = base_opts(args);
    opts.s_max = args.get_f64("smax", 0.8);
    let evals = args.get_usize("eval-batches", 8);
    let mut table = Table::new(
        "Fig.11 — PPL vs dense-layer placement (paper: dense-on-the-right wins)",
        &["L", "side", "PPL"],
    );
    let (_, ppl0, _) = run_one(&rt, "gpt2s-sim", opts.clone(), evals)?;
    table.row(&["0".into(), "-".into(), format!("{ppl0:.2}")]);
    for l in [1usize, 2] {
        for (side, left, right) in [("left", l, 0), ("right", 0, l)] {
            let o = PretrainOptions {
                dense_left: left,
                dense_right: right,
                ..opts.clone()
            };
            let (_, ppl, _) = run_one(&rt, "gpt2s-sim", o, evals)?;
            table.row(&[l.to_string(), side.into(), format!("{ppl:.2}")]);
        }
    }
    table.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// dense-vs-sparse training-step A/B harness
// ---------------------------------------------------------------------------

fn random_masks_for(
    cfg: &crate::runtime::ConfigInfo,
    s: f64,
    rng: &mut Rng,
) -> BTreeMap<String, BlockMask> {
    cfg.masks
        .iter()
        .map(|(n, sh)| (n.clone(), BlockMask::random(sh[0], sh[1], s, rng)))
        .collect()
}

/// Time one native train step (fwd + bwd + Adam) at a fixed mask set.
fn time_step(
    cfg: &crate::runtime::ConfigInfo,
    exec: MlpExec,
    masks: &BTreeMap<String, BlockMask>,
    batch: &crate::data::corpus::LmBatch,
    quick: bool,
) -> Result<f64> {
    let mut be = NativeBackend::with_exec(cfg, exec)?;
    let mut state = TrainState::new(ParamStore::init(cfg, 2));
    let budget = if quick {
        Duration::from_millis(400)
    } else {
        Duration::from_millis(2500)
    };
    let reps = if quick { 3 } else { 5 };
    let meas = bench_cfg("train-step", budget, reps, &mut || {
        be.train_step(&mut state, masks, batch, false).unwrap();
    });
    Ok(meas.secs())
}

/// `blast exp pretrain` — dense-vs-block-sparse **training step** A/B on
/// the native backend; writes `BENCH_pretrain.json` (override `--out`).
///
/// The dense arm runs the masked-dense GEMM path over all-ones masks (what
/// a dense-only trainer pays); each sparse arm runs the BSpMM
/// forward/backward at a fixed mask sparsity `s` — the step times a run
/// pays as the cubic schedule passes through `s`. **Gate: block-sparse
/// step ≥ 1.3× faster than dense at 80% MLP sparsity.** Flags:
/// `--config gpt2s-sim|llama-sim|…`, `--sparsities 0.0,0.5,0.8,0.9`,
/// `--quick`.
pub fn pretrain_ab(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_pretrain.json");
    let config = args.get_str("config", "gpt2s-sim");
    let cfg = sim_config(&config)
        .ok_or_else(|| anyhow::anyhow!("no built-in config {config:?}"))?;
    let sparsities = args.get_f64_list("sparsities", &[0.0, 0.5, 0.8, 0.9]);
    let mut rng = Rng::new(0xB1A5);
    let mut corpus = Corpus::new(cfg.vocab, 8, 0xB1A5);
    let batch = corpus.batch(cfg.batch, cfg.seq);

    // correctness first: both execution modes are the same math on the
    // exact geometry being timed (loss + one weight-gradient spot check)
    {
        let masks = random_masks_for(&cfg, 0.8, &mut rng.fork(1));
        let params = ParamStore::init(&cfg, 1);
        let mut d = NativeBackend::with_exec(&cfg, MlpExec::Dense)?;
        let mut s = NativeBackend::with_exec(&cfg, MlpExec::Sparse)?;
        let (ld, gd) = d.loss_and_grads(&params, &masks, &batch)?;
        let (ls, gs) = s.loss_and_grads(&params, &masks, &batch)?;
        ensure!(
            (ld - ls).abs() < 1e-3,
            "dense/sparse exec diverged: {ld} vs {ls}"
        );
        let w = &cfg.mlp_weights[0];
        let diff = gd.req(w).max_abs_diff(gs.req(w));
        ensure!(diff < 1e-3, "dense/sparse dW diverged: {diff}");
    }

    let mut report = JsonReport::new("pretrain");
    report.meta("isa", Json::str(crate::kernels::simd::dispatch().isa.name()));
    report.meta(
        "threads",
        Json::num(crate::util::threadpool::global().workers() as f64),
    );
    report.meta("config", Json::str(&cfg.name));
    report.meta("batch", Json::num(cfg.batch as f64));
    report.meta("seq", Json::num(cfg.seq as f64));
    report.meta("block", Json::num(cfg.block as f64));

    let mut table = Table::new(
        &format!(
            "Native train step, dense vs block-sparse — {} (gate: >= 1.3x at s=0.8)",
            cfg.name
        ),
        &["mlp exec", "sparsity", "schedule iter (m=10k)", "step", "speedup"],
    );
    let t_dense = {
        let ones: BTreeMap<String, BlockMask> = cfg
            .masks
            .iter()
            .map(|(n, sh)| (n.clone(), BlockMask::ones(sh[0], sh[1])))
            .collect();
        time_step(&cfg, MlpExec::Dense, &ones, &batch, quick)?
    };
    table.row(&[
        "dense".into(),
        "0.00".into(),
        "0".into(),
        fmt_time(t_dense),
        "1.00x".into(),
    ]);
    report.push(Json::obj(vec![
        ("exec", Json::str("dense")),
        ("sparsity", Json::num(0.0)),
        ("step_ns", Json::num(t_dense * 1e9)),
        ("speedup", Json::num(1.0)),
    ]));

    // where each sparsity lands on a paper-scale cubic schedule (context
    // for reading the rows as points along one training run)
    let sched = SparsitySchedule::new(0.0, 0.95, 10_000, 0);
    let mut gate: Option<(f64, bool)> = None;
    for &s in &sparsities {
        let masks = random_masks_for(&cfg, s, &mut rng.fork((s * 1000.0) as u64));
        let t_sparse = time_step(&cfg, MlpExec::Sparse, &masks, &batch, quick)?;
        let speedup = t_dense / t_sparse;
        let at = sched
            .first_iter_reaching(s)
            .map(|i| i.to_string())
            .unwrap_or_else(|| "-".into());
        if (s - 0.8).abs() < 1e-9 {
            gate = Some((speedup, speedup >= 1.3));
        }
        table.row(&[
            "bspmm".into(),
            format!("{s:.2}"),
            at,
            fmt_time(t_sparse),
            format!("{speedup:.2}x"),
        ]);
        report.push(Json::obj(vec![
            ("exec", Json::str("sparse")),
            ("sparsity", Json::num(s)),
            ("step_ns", Json::num(t_sparse * 1e9)),
            ("speedup", Json::num(speedup)),
        ]));
    }
    table.print();

    report.write(std::path::Path::new(&out_path))?;
    println!("\nwrote {} rows to {out_path}", report.len());
    match gate {
        Some((speedup, ok)) => println!(
            "gate (block-sparse step >= 1.3x dense at 80% MLP sparsity): {} ({speedup:.2}x)",
            if ok { "PASS" } else { "FAIL" }
        ),
        None => println!(
            "gate (block-sparse step >= 1.3x dense at 80% MLP sparsity): \
             N/A — pass --sparsities with 0.8"
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The harness's two arms agree before any timing (the same check the
    /// driver runs, on the micro twin so the test stays fast).
    #[test]
    fn harness_arms_agree_on_micro() {
        let cfg = sim_config("micro").unwrap();
        let mut rng = Rng::new(3);
        let masks = random_masks_for(&cfg, 0.8, &mut rng);
        let mut corpus = Corpus::new(cfg.vocab, 8, 4);
        let batch = corpus.batch(cfg.batch, cfg.seq);
        let params = ParamStore::init(&cfg, 5);
        let mut d = NativeBackend::with_exec(&cfg, MlpExec::Dense).unwrap();
        let mut s = NativeBackend::with_exec(&cfg, MlpExec::Sparse).unwrap();
        let (ld, _) = d.loss_and_grads(&params, &masks, &batch).unwrap();
        let (ls, _) = s.loss_and_grads(&params, &masks, &batch).unwrap();
        assert!((ld - ls).abs() < 1e-3, "{ld} vs {ls}");
    }

    #[test]
    fn backend_flag_rejects_unknown() {
        let args = Args::parse_from(vec!["--backend".into(), "tpu".into()]);
        assert!(open_backend(&args).is_err());
    }

    #[test]
    fn native_backend_is_default_choice() {
        let args = Args::parse_from(Vec::new());
        let rt = open_backend(&args).unwrap();
        assert!(rt.is_none(), "default must not require the PJRT runtime");
    }
}
