//! Fig. 7 — inference memory footprint / GPU-count model.

use anyhow::Result;

use crate::model::config::paper_catalog;
use crate::perf::memory::{gpus_required, reduction_factor, weight_bytes};
use crate::testkit::bench::Table;
use crate::util::cli::Args;

/// Fig. 7: GH200s (96 GB) required for FP32 weights, dense vs sparse.
pub fn fig7(args: &Args) -> Result<()> {
    let block = args.get_usize("block", 128);
    let sparsities = args.get_f64_list("sparsities", &[0.7, 0.8, 0.9, 0.95]);
    let mut table = Table::new(
        "Fig.7 — #GH200 (96GB) for FP32 weights (paper: 405B dense 17 → ~6, 2.9x fewer)",
        &["model", "dense GB", "dense GPUs", "s", "sparse GB", "sparse GPUs", "GPU ratio", "mem reduction"],
    );
    for g in paper_catalog() {
        if !g.name.starts_with("Llama") {
            continue;
        }
        let dense_b = weight_bytes(&g, 0.0, block);
        let dense_g = gpus_required(&g, 0.0, block);
        for &s in &sparsities {
            let sb = weight_bytes(&g, s, block);
            let sg = gpus_required(&g, s, block);
            table.row(&[
                g.name.to_string(),
                format!("{:.0}", dense_b / 1e9),
                dense_g.to_string(),
                format!("{:.0}%", s * 100.0),
                format!("{:.0}", sb / 1e9),
                sg.to_string(),
                format!("{:.2}x", dense_g as f64 / sg as f64),
                format!("{:.2}x", reduction_factor(&g, s, block)),
            ]);
        }
    }
    table.print();
    println!("\npaper check: Llama-3.1-405B dense needs 17 GPUs; @80% ~6 GPUs (2.8-2.9x).");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_runs() {
        fig7(&Args::default()).unwrap();
    }
}
