//! Attention-path experiments: the tiled/paged-vs-seed A/B and the
//! paged-KV memory-footprint check.
//!
//! `blast exp attention` (or `cargo bench --bench attention_ab`) measures
//! three things on one machine and writes `BENCH_attention.json`:
//!
//! * **Tiled prefill** — [`crate::kernels::attention::causal_attention`]
//!   (q-tile × k-tile packed micro-GEMMs + streaming softmax) vs the
//!   retained seed scalar path
//!   ([`crate::kernels::attention::causal_attention_ref`]), checked
//!   against it within 1e-5 abs on every run. **Gate: ≥ 1.5× at
//!   `seq ≥ 512`.**
//! * **Paged decode** — the page-walking unrolled-dot kernel
//!   ([`crate::kernels::attention::decode_head_paged_into`]) vs the seed
//!   flat decode ([`crate::kernels::attention::decode_attention_ref`]),
//!   informational rows (decode is bandwidth-bound; the win is layout).
//! * **Resident KV memory** — a 64-token session on a paged engine vs
//!   the seed's flat `max_seq` preallocation bound. **Gate: flat ≥ 4×
//!   resident.**
//!
//! Results land next to `BENCH_kernels.json` / `BENCH_serve.json` in the
//! perf-trajectory convention (see README).

use anyhow::{bail, Result};

use crate::eval::kernel_exps::fig6_params;
use crate::kernels::attention::{
    causal_attention, causal_attention_ref, causal_attention_thresh, decode_attention_ref,
    decode_head_paged_into, AttnCounters, AttnThreshold,
};
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::engine::{AttnOptions, Engine, MlpMode};
use crate::model::kv::KvOptions;
use crate::testkit::bench::{bench_cfg, black_box, fmt_time, JsonReport, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;
use std::collections::BTreeMap;
use std::time::Duration;

fn meas<F: FnMut()>(name: &str, quick: bool, mut f: F) -> f64 {
    let budget = if quick {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(400)
    };
    bench_cfg(name, budget, if quick { 3 } else { 5 }, &mut f).secs()
}

/// Paged decode over all heads of a flat `(heads, max_seq, hd)` KV — the
/// same `(head)` fan-out as [`decode_attention_ref`], with the paged
/// kernel walking `page`-position stripes of the flat buffer (a flat
/// buffer serves any page size: stripe `pi` is the slice at `pi*page*hd`).
#[allow(clippy::too_many_arguments)] // mirrors the decode_attention_ref ABI + page
fn decode_paged_all_heads(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    heads: usize,
    max_seq: usize,
    hd: usize,
    pos: usize,
    page: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        let kh = &kcache[h * max_seq * hd..(h + 1) * max_seq * hd];
        let vh = &vcache[h * max_seq * hd..(h + 1) * max_seq * hd];
        // SAFETY: disjoint per-head stripes; parallel_for blocks.
        let orow = unsafe {
            std::slice::from_raw_parts_mut((out_base as *mut f32).add(h * hd), hd)
        };
        decode_head_paged_into(
            &q[h * hd..(h + 1) * hd],
            hd,
            page,
            pos,
            |pi| (&kh[pi * page * hd..], &vh[pi * page * hd..]),
            orow,
        );
    });
    out
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// `blast exp attention` — tiled/paged attention A/B + paged-KV memory
/// check; writes `BENCH_attention.json` (override with `--out`). Flags:
/// `--seqs 128,256,512`, `--heads H`, `--hd D`, `--kv-page P`, `--quick`.
pub fn attention(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_attention.json");
    let seqs = args.get_usize_list("seqs", if quick { &[128, 512] } else { &[128, 256, 512] });
    let heads = args.get_usize("heads", 8);
    let hd = args.get_usize("hd", 64);
    let page = args.get_usize("kv-page", 64);
    if page == 0 {
        bail!("--kv-page must be >= 1");
    }

    let mut report = JsonReport::new("attention");
    report.meta("isa", Json::str(crate::kernels::simd::dispatch().isa.name()));
    report.meta(
        "threads",
        Json::num(crate::util::threadpool::global().workers() as f64),
    );
    report.meta("heads", Json::num(heads as f64));
    report.meta("hd", Json::num(hd as f64));
    report.meta("kv_page", Json::num(page as f64));
    let mut rng = Rng::new(0xA77E);

    // ---- tiled prefill vs seed scalar path ----
    let mut table = Table::new(
        "Tiled streaming-softmax prefill vs seed scalar attention (gate: >= 1.5x at seq >= 512)",
        &["kernel", "seq", "heads", "hd", "seed", "tiled", "speedup", "oracle-diff"],
    );
    let mut gate_prefill_ok = true;
    let mut gated_rows = 0usize;
    for &seq in &seqs {
        let q = rng.normal_vec(heads * seq * hd, 1.0);
        let k = rng.normal_vec(heads * seq * hd, 1.0);
        let v = rng.normal_vec(heads * seq * hd, 1.0);
        // correctness first: the tiled kernel must sit within 1e-5 abs of
        // the retained oracle on the exact operands being timed
        let want = causal_attention_ref(&q, &k, &v, heads, seq, hd);
        let got = causal_attention(&q, &k, &v, heads, seq, hd);
        let diff = max_abs_diff(&got, &want);
        if diff > 1e-5 {
            bail!("tiled prefill diverged from seed oracle: {diff} at seq={seq}");
        }
        let t_ref = meas("causal-ref", quick, || {
            black_box(causal_attention_ref(&q, &k, &v, heads, seq, hd));
        });
        let t_new = meas("causal-tiled", quick, || {
            black_box(causal_attention(&q, &k, &v, heads, seq, hd));
        });
        let speedup = t_ref / t_new;
        if seq >= 512 {
            gated_rows += 1;
            if speedup < 1.5 {
                gate_prefill_ok = false;
            }
        }
        table.row(&[
            "prefill".into(),
            seq.to_string(),
            heads.to_string(),
            hd.to_string(),
            fmt_time(t_ref),
            fmt_time(t_new),
            format!("{speedup:.2}x"),
            format!("{diff:.1e}"),
        ]);
        report.push(Json::obj(vec![
            ("kernel", Json::str("prefill")),
            ("seq", Json::num(seq as f64)),
            ("seed_ns", Json::num(t_ref * 1e9)),
            ("tiled_ns", Json::num(t_new * 1e9)),
            ("speedup", Json::num(speedup)),
            ("max_abs_diff", Json::num(diff as f64)),
        ]));
    }
    table.print();

    // ---- paged decode vs seed flat decode (informational) ----
    let mut dtable = Table::new(
        "Paged decode walk vs seed flat decode (informational; the win is layout)",
        &["kernel", "pos", "page", "seed", "paged", "speedup", "oracle-diff"],
    );
    let dposs: &[usize] = if quick { &[255] } else { &[63, 255, 511] };
    for &pos in dposs {
        let max_seq = pos + 1;
        let q = rng.normal_vec(heads * hd, 1.0);
        let k = rng.normal_vec(heads * max_seq * hd, 1.0);
        let v = rng.normal_vec(heads * max_seq * hd, 1.0);
        let want = decode_attention_ref(&q, &k, &v, heads, max_seq, hd, pos);
        let got = decode_paged_all_heads(&q, &k, &v, heads, max_seq, hd, pos, page);
        let diff = max_abs_diff(&got, &want);
        if diff > 1e-5 {
            bail!("paged decode diverged from seed oracle: {diff} at pos={pos}");
        }
        let t_ref = meas("decode-ref", quick, || {
            black_box(decode_attention_ref(&q, &k, &v, heads, max_seq, hd, pos));
        });
        let t_new = meas("decode-paged", quick, || {
            black_box(decode_paged_all_heads(&q, &k, &v, heads, max_seq, hd, pos, page));
        });
        let speedup = t_ref / t_new;
        dtable.row(&[
            "decode".into(),
            pos.to_string(),
            page.to_string(),
            fmt_time(t_ref),
            fmt_time(t_new),
            format!("{speedup:.2}x"),
            format!("{diff:.1e}"),
        ]);
        report.push(Json::obj(vec![
            ("kernel", Json::str("decode")),
            ("pos", Json::num(pos as f64)),
            ("page", Json::num(page as f64)),
            ("seed_ns", Json::num(t_ref * 1e9)),
            ("paged_ns", Json::num(t_new * 1e9)),
            ("speedup", Json::num(speedup)),
            ("max_abs_diff", Json::num(diff as f64)),
        ]));
    }
    dtable.print();

    // ---- BLASST threshold-skipped prefill vs the exact tiled kernel ----
    // The A/B whose win grows with context length: same tiled kernel,
    // with k-tile rows whose score max sits more than τ below the running
    // row max skipped (shifted exp, P build and P·V all elided). Skipped
    // post-softmax mass is bounded by tq·TK·e^(−τ), so drift shrinks
    // exponentially in τ while the skipped fraction (and speedup) grows
    // with seq. `--attn-threshold TAU` pins a single τ; `--attn-taus
    // 2,4,8` sweeps.
    let taus: Vec<f64> = match args.get_threshold("attn-threshold") {
        Some(t) => vec![t as f64],
        None => args.get_f64_list("attn-taus", &[2.0, 4.0, 8.0]),
    };
    let bseqs = args.get_usize_list(
        "blasst-seqs",
        if quick { &[512, 2048] } else { &[512, 2048, 8192] },
    );
    let mut btable = Table::new(
        "BLASST threshold-skipped prefill vs exact tiled kernel (skip fraction x speedup; drift <= tq*TK*e^-tau per tile round)",
        &["kernel", "seq", "tau", "rows-skipped", "tiles-skipped", "exact", "thresh", "speedup", "drift"],
    );
    for &seq in &bseqs {
        let q = rng.normal_vec(heads * seq * hd, 1.0);
        let k = rng.normal_vec(heads * seq * hd, 1.0);
        let v = rng.normal_vec(heads * seq * hd, 1.0);
        let exact = causal_attention(&q, &k, &v, heads, seq, hd);
        let t_exact = meas("blasst-exact", quick, || {
            black_box(causal_attention(&q, &k, &v, heads, seq, hd));
        });
        for &tau in &taus {
            let counters = AttnCounters::new();
            let th = AttnThreshold { tau: tau as f32, counters: &counters };
            let got = causal_attention_thresh(&q, &k, &v, heads, seq, hd, Some(th));
            let drift = max_abs_diff(&got, &exact);
            // one-pass skip census before the clock starts inflating it
            let st = counters.snapshot();
            let t_thresh = meas("blasst-thresh", quick, || {
                black_box(causal_attention_thresh(&q, &k, &v, heads, seq, hd, Some(th)));
            });
            let speedup = t_exact / t_thresh;
            btable.row(&[
                "blasst-prefill".into(),
                seq.to_string(),
                format!("{tau}"),
                format!("{:.1}%", st.row_skip_frac() * 100.0),
                format!("{:.1}%", st.tile_skip_frac() * 100.0),
                fmt_time(t_exact),
                fmt_time(t_thresh),
                format!("{speedup:.2}x"),
                format!("{drift:.1e}"),
            ]);
            report.push(Json::obj(vec![
                ("kernel", Json::str("blasst-prefill")),
                ("seq", Json::num(seq as f64)),
                ("tau", Json::num(tau)),
                ("row_skip_frac", Json::num(st.row_skip_frac())),
                ("tile_skip_frac", Json::num(st.tile_skip_frac())),
                ("exact_ns", Json::num(t_exact * 1e9)),
                ("thresh_ns", Json::num(t_thresh * 1e9)),
                ("speedup", Json::num(speedup)),
                ("max_abs_drift", Json::num(drift as f64)),
            ]));
        }
    }
    btable.print();

    // ---- accuracy: end-to-end logit drift vs exact across the τ sweep ----
    // The same knob measured where it matters: an engine pair (exact vs
    // threshold-armed) prefilling real prompts and decoding a few greedy
    // steps, reporting max/mean logit drift plus the skip census from the
    // armed engine's counters. Exact attention is the τ=off default; this
    // table is what the README's accuracy-vs-speed tradeoff quotes.
    let acc_cfg = NativeConfig {
        name: "attn-acc-twin".into(),
        kind: ModelKind::Llama,
        vocab: 256,
        emb: 256,
        ffn: 512,
        layers: 2,
        heads,
        max_seq: 512,
        block: 32,
    };
    let acc_params = fig6_params(&acc_cfg, 9);
    let acc_kv = KvOptions { page, pool_pages: None, prefix_cache: true };
    let exact_eng = Engine::new_with_kv(
        acc_cfg.clone(),
        &acc_params,
        &BTreeMap::new(),
        MlpMode::Dense,
        acc_kv,
    )?;
    let n_prompts = if quick { 2 } else { 4 };
    let decode_steps = if quick { 2 } else { 4 };
    let prompts: Vec<Vec<u32>> = (0..n_prompts)
        .map(|p| {
            (0..(96 + 64 * p))
                .map(|i| ((i * 37 + p * 101) % acc_cfg.vocab) as u32)
                .collect()
        })
        .collect();
    // exact side once: logits per prompt at prefill + each decode step,
    // with the greedy tokens that drive both engines (same operands)
    let mut exact_logits: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut drive_tokens: Vec<Vec<u32>> = Vec::new();
    for prompt in &prompts {
        let mut cache = exact_eng.new_cache();
        let mut per = vec![exact_eng.prefill(prompt, &mut cache)?];
        let mut toks = vec![Engine::argmax(&per[0])];
        for s in 0..decode_steps {
            per.push(exact_eng.decode(toks[s], &mut cache)?);
            toks.push(Engine::argmax(per.last().unwrap()));
        }
        exact_logits.push(per);
        drive_tokens.push(toks);
    }
    let mut atable = Table::new(
        "Logit drift vs exact attention across the tau sweep (engine prefill + greedy decode)",
        &["tau", "max-drift", "mean-drift", "rows-skipped", "pages-skipped"],
    );
    for &tau in &taus {
        let armed = Engine::new_with_opts(
            acc_cfg.clone(),
            &acc_params,
            &BTreeMap::new(),
            MlpMode::Dense,
            acc_kv,
            AttnOptions { threshold: Some(tau as f32) },
        )?;
        let (mut max_drift, mut sum_drift, mut n_vals) = (0.0f64, 0.0f64, 0u64);
        for (pi, prompt) in prompts.iter().enumerate() {
            let mut cache = armed.new_cache();
            let mut got = vec![armed.prefill(prompt, &mut cache)?];
            for s in 0..decode_steps {
                got.push(armed.decode(drive_tokens[pi][s], &mut cache)?);
            }
            for (g, e) in got.iter().zip(&exact_logits[pi]) {
                for (a, b) in g.iter().zip(e.iter()) {
                    let d = (*a as f64 - *b as f64).abs();
                    max_drift = max_drift.max(d);
                    sum_drift += d;
                    n_vals += 1;
                }
            }
        }
        let mean_drift = sum_drift / n_vals.max(1) as f64;
        let st = armed.attn_stats();
        atable.row(&[
            format!("{tau}"),
            format!("{max_drift:.2e}"),
            format!("{mean_drift:.2e}"),
            format!("{}/{} ({:.1}%)", st.rows_skipped, st.rows, st.row_skip_frac() * 100.0),
            format!("{}/{} ({:.1}%)", st.pages_skipped, st.pages, st.page_skip_frac() * 100.0),
        ]);
        report.push(Json::obj(vec![
            ("kernel", Json::str("accuracy")),
            ("tau", Json::num(tau)),
            ("max_logit_drift", Json::num(max_drift)),
            ("mean_logit_drift", Json::num(mean_drift)),
            ("row_skip_frac", Json::num(st.row_skip_frac())),
            ("page_skip_frac", Json::num(st.page_skip_frac())),
        ]));
    }
    atable.print();

    // ---- resident KV memory: 64-token session, paged vs flat bound ----
    // A long-context engine (the deployment shape paging exists for): the
    // seed cache preallocated max_seq for every session regardless of use.
    let cfg = NativeConfig {
        name: "attn-mem-twin".into(),
        kind: ModelKind::Llama,
        vocab: 256,
        emb: 512,
        ffn: 1024,
        layers: 4,
        heads: 8,
        max_seq: 1024,
        block: 32,
    };
    let params = fig6_params(&cfg, 7);
    let engine = Engine::new_with_kv(
        cfg.clone(),
        &params,
        &BTreeMap::new(),
        MlpMode::Dense,
        KvOptions { page, pool_pages: None, prefix_cache: true },
    )?;
    let tokens = 64usize;
    let prompt: Vec<u32> = (0..tokens).map(|i| (i * 37 % cfg.vocab) as u32).collect();
    let mut cache = engine.new_cache();
    engine.prefill(&prompt, &mut cache)?;
    let resident = cache.bytes();
    let flat = engine.flat_kv_bytes();
    let ratio = flat as f64 / resident.max(1) as f64;
    let gate_mem_ok = flat >= 4 * resident;
    println!(
        "\n== Resident KV for a {tokens}-token session (page={page}, max_seq={}) ==",
        cfg.max_seq
    );
    println!(
        "paged resident: {:.1} KiB   flat max_seq bound: {:.1} KiB   ratio: {ratio:.1}x",
        resident as f64 / 1024.0,
        flat as f64 / 1024.0
    );
    report.push(Json::obj(vec![
        ("kernel", Json::str("kv-memory")),
        ("tokens", Json::num(tokens as f64)),
        ("page", Json::num(page as f64)),
        ("max_seq", Json::num(cfg.max_seq as f64)),
        ("resident_bytes", Json::num(resident as f64)),
        ("flat_bytes", Json::num(flat as f64)),
        ("ratio", Json::num(ratio)),
    ]));

    report.write(std::path::Path::new(&out_path))?;
    println!("\nwrote {} rows to {out_path}", report.len());
    println!(
        "gate (tiled prefill >= 1.5x seed at seq >= 512): {}",
        if gated_rows == 0 {
            "N/A — no seq >= 512 measured (pass --seqs with a value >= 512)"
        } else if gate_prefill_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );
    println!(
        "gate (64-token resident KV >= 4x below flat max_seq bound): {} ({ratio:.1}x)",
        if gate_mem_ok { "PASS" } else { "FAIL" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The harness's two comparison paths agree on small shapes (the same
    /// check the driver runs before timing, minus the clock).
    #[test]
    fn harness_oracles_agree_on_small_shapes() {
        let (heads, seq, hd) = (2usize, 40usize, 12usize);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(heads * seq * hd, 1.0);
        let k = rng.normal_vec(heads * seq * hd, 1.0);
        let v = rng.normal_vec(heads * seq * hd, 1.0);
        let a = causal_attention(&q, &k, &v, heads, seq, hd);
        let b = causal_attention_ref(&q, &k, &v, heads, seq, hd);
        assert!(max_abs_diff(&a, &b) < 1e-5);

        let pos = seq - 1;
        let qd = rng.normal_vec(heads * hd, 1.0);
        let want = decode_attention_ref(&qd, &k, &v, heads, seq, hd, pos);
        for page in [3usize, 16, 64] {
            let got = decode_paged_all_heads(&qd, &k, &v, heads, seq, hd, pos, page);
            assert!(max_abs_diff(&got, &want) < 1e-5, "page={page}");
        }
    }
}
