//! Attention-path experiments: the tiled/paged-vs-seed A/B and the
//! paged-KV memory-footprint check.
//!
//! `blast exp attention` (or `cargo bench --bench attention_ab`) measures
//! three things on one machine and writes `BENCH_attention.json`:
//!
//! * **Tiled prefill** — [`crate::kernels::attention::causal_attention`]
//!   (q-tile × k-tile packed micro-GEMMs + streaming softmax) vs the
//!   retained seed scalar path
//!   ([`crate::kernels::attention::causal_attention_ref`]), checked
//!   against it within 1e-5 abs on every run. **Gate: ≥ 1.5× at
//!   `seq ≥ 512`.**
//! * **Paged decode** — the page-walking unrolled-dot kernel
//!   ([`crate::kernels::attention::decode_head_paged_into`]) vs the seed
//!   flat decode ([`crate::kernels::attention::decode_attention_ref`]),
//!   informational rows (decode is bandwidth-bound; the win is layout).
//! * **Resident KV memory** — a 64-token session on a paged engine vs
//!   the seed's flat `max_seq` preallocation bound. **Gate: flat ≥ 4×
//!   resident.**
//!
//! Results land next to `BENCH_kernels.json` / `BENCH_serve.json` in the
//! perf-trajectory convention (see README).

use anyhow::{bail, Result};

use crate::eval::kernel_exps::fig6_params;
use crate::kernels::attention::{
    causal_attention, causal_attention_ref, decode_attention_ref, decode_head_paged_into,
};
use crate::model::config::{ModelKind, NativeConfig};
use crate::model::engine::{Engine, MlpMode};
use crate::model::kv::KvOptions;
use crate::testkit::bench::{bench_cfg, black_box, fmt_time, JsonReport, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;
use std::collections::BTreeMap;
use std::time::Duration;

fn meas<F: FnMut()>(name: &str, quick: bool, mut f: F) -> f64 {
    let budget = if quick {
        Duration::from_millis(120)
    } else {
        Duration::from_millis(400)
    };
    bench_cfg(name, budget, if quick { 3 } else { 5 }, &mut f).secs()
}

/// Paged decode over all heads of a flat `(heads, max_seq, hd)` KV — the
/// same `(head)` fan-out as [`decode_attention_ref`], with the paged
/// kernel walking `page`-position stripes of the flat buffer (a flat
/// buffer serves any page size: stripe `pi` is the slice at `pi*page*hd`).
#[allow(clippy::too_many_arguments)] // mirrors the decode_attention_ref ABI + page
fn decode_paged_all_heads(
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    heads: usize,
    max_seq: usize,
    hd: usize,
    pos: usize,
    page: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; heads * hd];
    let out_base = out.as_mut_ptr() as usize;
    threadpool::parallel_for(heads, |h| {
        let kh = &kcache[h * max_seq * hd..(h + 1) * max_seq * hd];
        let vh = &vcache[h * max_seq * hd..(h + 1) * max_seq * hd];
        // SAFETY: disjoint per-head stripes; parallel_for blocks.
        let orow = unsafe {
            std::slice::from_raw_parts_mut((out_base as *mut f32).add(h * hd), hd)
        };
        decode_head_paged_into(
            &q[h * hd..(h + 1) * hd],
            hd,
            page,
            pos,
            |pi| (&kh[pi * page * hd..], &vh[pi * page * hd..]),
            orow,
        );
    });
    out
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// `blast exp attention` — tiled/paged attention A/B + paged-KV memory
/// check; writes `BENCH_attention.json` (override with `--out`). Flags:
/// `--seqs 128,256,512`, `--heads H`, `--hd D`, `--kv-page P`, `--quick`.
pub fn attention(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_attention.json");
    let seqs = args.get_usize_list("seqs", if quick { &[128, 512] } else { &[128, 256, 512] });
    let heads = args.get_usize("heads", 8);
    let hd = args.get_usize("hd", 64);
    let page = args.get_usize("kv-page", 64);
    if page == 0 {
        bail!("--kv-page must be >= 1");
    }

    let mut report = JsonReport::new("attention");
    report.meta("isa", Json::str(crate::kernels::simd::dispatch().isa.name()));
    report.meta(
        "threads",
        Json::num(crate::util::threadpool::global().workers() as f64),
    );
    report.meta("heads", Json::num(heads as f64));
    report.meta("hd", Json::num(hd as f64));
    report.meta("kv_page", Json::num(page as f64));
    let mut rng = Rng::new(0xA77E);

    // ---- tiled prefill vs seed scalar path ----
    let mut table = Table::new(
        "Tiled streaming-softmax prefill vs seed scalar attention (gate: >= 1.5x at seq >= 512)",
        &["kernel", "seq", "heads", "hd", "seed", "tiled", "speedup", "oracle-diff"],
    );
    let mut gate_prefill_ok = true;
    let mut gated_rows = 0usize;
    for &seq in &seqs {
        let q = rng.normal_vec(heads * seq * hd, 1.0);
        let k = rng.normal_vec(heads * seq * hd, 1.0);
        let v = rng.normal_vec(heads * seq * hd, 1.0);
        // correctness first: the tiled kernel must sit within 1e-5 abs of
        // the retained oracle on the exact operands being timed
        let want = causal_attention_ref(&q, &k, &v, heads, seq, hd);
        let got = causal_attention(&q, &k, &v, heads, seq, hd);
        let diff = max_abs_diff(&got, &want);
        if diff > 1e-5 {
            bail!("tiled prefill diverged from seed oracle: {diff} at seq={seq}");
        }
        let t_ref = meas("causal-ref", quick, || {
            black_box(causal_attention_ref(&q, &k, &v, heads, seq, hd));
        });
        let t_new = meas("causal-tiled", quick, || {
            black_box(causal_attention(&q, &k, &v, heads, seq, hd));
        });
        let speedup = t_ref / t_new;
        if seq >= 512 {
            gated_rows += 1;
            if speedup < 1.5 {
                gate_prefill_ok = false;
            }
        }
        table.row(&[
            "prefill".into(),
            seq.to_string(),
            heads.to_string(),
            hd.to_string(),
            fmt_time(t_ref),
            fmt_time(t_new),
            format!("{speedup:.2}x"),
            format!("{diff:.1e}"),
        ]);
        report.push(Json::obj(vec![
            ("kernel", Json::str("prefill")),
            ("seq", Json::num(seq as f64)),
            ("seed_ns", Json::num(t_ref * 1e9)),
            ("tiled_ns", Json::num(t_new * 1e9)),
            ("speedup", Json::num(speedup)),
            ("max_abs_diff", Json::num(diff as f64)),
        ]));
    }
    table.print();

    // ---- paged decode vs seed flat decode (informational) ----
    let mut dtable = Table::new(
        "Paged decode walk vs seed flat decode (informational; the win is layout)",
        &["kernel", "pos", "page", "seed", "paged", "speedup", "oracle-diff"],
    );
    let dposs: &[usize] = if quick { &[255] } else { &[63, 255, 511] };
    for &pos in dposs {
        let max_seq = pos + 1;
        let q = rng.normal_vec(heads * hd, 1.0);
        let k = rng.normal_vec(heads * max_seq * hd, 1.0);
        let v = rng.normal_vec(heads * max_seq * hd, 1.0);
        let want = decode_attention_ref(&q, &k, &v, heads, max_seq, hd, pos);
        let got = decode_paged_all_heads(&q, &k, &v, heads, max_seq, hd, pos, page);
        let diff = max_abs_diff(&got, &want);
        if diff > 1e-5 {
            bail!("paged decode diverged from seed oracle: {diff} at pos={pos}");
        }
        let t_ref = meas("decode-ref", quick, || {
            black_box(decode_attention_ref(&q, &k, &v, heads, max_seq, hd, pos));
        });
        let t_new = meas("decode-paged", quick, || {
            black_box(decode_paged_all_heads(&q, &k, &v, heads, max_seq, hd, pos, page));
        });
        let speedup = t_ref / t_new;
        dtable.row(&[
            "decode".into(),
            pos.to_string(),
            page.to_string(),
            fmt_time(t_ref),
            fmt_time(t_new),
            format!("{speedup:.2}x"),
            format!("{diff:.1e}"),
        ]);
        report.push(Json::obj(vec![
            ("kernel", Json::str("decode")),
            ("pos", Json::num(pos as f64)),
            ("page", Json::num(page as f64)),
            ("seed_ns", Json::num(t_ref * 1e9)),
            ("paged_ns", Json::num(t_new * 1e9)),
            ("speedup", Json::num(speedup)),
            ("max_abs_diff", Json::num(diff as f64)),
        ]));
    }
    dtable.print();

    // ---- resident KV memory: 64-token session, paged vs flat bound ----
    // A long-context engine (the deployment shape paging exists for): the
    // seed cache preallocated max_seq for every session regardless of use.
    let cfg = NativeConfig {
        name: "attn-mem-twin".into(),
        kind: ModelKind::Llama,
        vocab: 256,
        emb: 512,
        ffn: 1024,
        layers: 4,
        heads: 8,
        max_seq: 1024,
        block: 32,
    };
    let params = fig6_params(&cfg, 7);
    let engine = Engine::new_with_kv(
        cfg.clone(),
        &params,
        &BTreeMap::new(),
        MlpMode::Dense,
        KvOptions { page, pool_pages: None, prefix_cache: true },
    )?;
    let tokens = 64usize;
    let prompt: Vec<u32> = (0..tokens).map(|i| (i * 37 % cfg.vocab) as u32).collect();
    let mut cache = engine.new_cache();
    engine.prefill(&prompt, &mut cache)?;
    let resident = cache.bytes();
    let flat = engine.flat_kv_bytes();
    let ratio = flat as f64 / resident.max(1) as f64;
    let gate_mem_ok = flat >= 4 * resident;
    println!(
        "\n== Resident KV for a {tokens}-token session (page={page}, max_seq={}) ==",
        cfg.max_seq
    );
    println!(
        "paged resident: {:.1} KiB   flat max_seq bound: {:.1} KiB   ratio: {ratio:.1}x",
        resident as f64 / 1024.0,
        flat as f64 / 1024.0
    );
    report.push(Json::obj(vec![
        ("kernel", Json::str("kv-memory")),
        ("tokens", Json::num(tokens as f64)),
        ("page", Json::num(page as f64)),
        ("max_seq", Json::num(cfg.max_seq as f64)),
        ("resident_bytes", Json::num(resident as f64)),
        ("flat_bytes", Json::num(flat as f64)),
        ("ratio", Json::num(ratio)),
    ]));

    report.write(std::path::Path::new(&out_path))?;
    println!("\nwrote {} rows to {out_path}", report.len());
    println!(
        "gate (tiled prefill >= 1.5x seed at seq >= 512): {}",
        if gated_rows == 0 {
            "N/A — no seq >= 512 measured (pass --seqs with a value >= 512)"
        } else if gate_prefill_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );
    println!(
        "gate (64-token resident KV >= 4x below flat max_seq bound): {} ({ratio:.1}x)",
        if gate_mem_ok { "PASS" } else { "FAIL" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The harness's two comparison paths agree on small shapes (the same
    /// check the driver runs before timing, minus the clock).
    #[test]
    fn harness_oracles_agree_on_small_shapes() {
        let (heads, seq, hd) = (2usize, 40usize, 12usize);
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(heads * seq * hd, 1.0);
        let k = rng.normal_vec(heads * seq * hd, 1.0);
        let v = rng.normal_vec(heads * seq * hd, 1.0);
        let a = causal_attention(&q, &k, &v, heads, seq, hd);
        let b = causal_attention_ref(&q, &k, &v, heads, seq, hd);
        assert!(max_abs_diff(&a, &b) < 1e-5);

        let pos = seq - 1;
        let qd = rng.normal_vec(heads * hd, 1.0);
        let want = decode_attention_ref(&qd, &k, &v, heads, seq, hd, pos);
        for page in [3usize, 16, 64] {
            let got = decode_paged_all_heads(&qd, &k, &v, heads, seq, hd, pos, page);
            assert!(max_abs_diff(&got, &want) < 1e-5, "page={page}");
        }
    }
}
