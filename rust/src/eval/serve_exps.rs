//! Serving-level experiments: the batched-vs-sequential decode A/B.
//!
//! `blast exp serve` (or `cargo bench --bench serve_ab`) measures one
//! continuous-batching decode *round* both ways on the same engine and
//! weights:
//!
//! * **sequential** — B calls to `Engine::decode`, each a chain of 1-row
//!   GEMVs over the prepacked weights (the pre-batching coordinator);
//! * **batched** — one `Engine::decode_batch` call whose projections, MLP
//!   and LM head run as single `(B × d_model)` packed GEMM/BSpMM sweeps.
//!
//! Both paths produce bit-identical greedy streams (asserted here on every
//! run), so the A/B isolates pure execution efficiency: how much weight
//! panel / BCSC block streaming is amortized once the kernels see a real
//! batch dimension. Results go to `BENCH_serve.json` via
//! [`crate::testkit::bench::JsonReport`] — the serving-throughput
//! trajectory file, next to `BENCH_kernels.json`. Gate: batched round
//! throughput ≥ 1.5× sequential at batch ≥ 4, dense *and* sparse.
//!
//! A second arm measures the KV prefix cache: B sessions repeating one
//! page-aligned prompt prefix with distinct tails, prefilled once with
//! sharing on and once with it off. Streams must again be bitwise
//! identical; the arm reports prefill speedup, hit rate, pages shared,
//! and physical-vs-logical page residency as `"arm": "shared_prefix"`
//! rows in the same report.
//!
//! A third arm exercises the replicated fleet tier: the same request load
//! through 1/2/4-replica fleets (`"arm": "fleet"` rows — replica scaling)
//! plus a chaos run with `replica_crash`/`replica_stall_ms` armed that
//! reports failover counts and worst-case end-to-end latency. Every
//! successful stream must be bitwise identical to the 1-replica clean run
//! — the failover-replay guarantee, asserted on every bench invocation.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::{BatcherConfig, CompletionWait, Fleet, FleetConfig, Request};
use crate::eval::kernel_exps::{fig6_config, fig6_params, random_masks};
use crate::model::engine::{Engine, KvCache, MlpMode};
use crate::model::kv::KvOptions;
use crate::testkit::bench::{fmt_time, JsonReport, Table};
use crate::util::cli::Args;
use crate::util::faults::Faults;
use crate::util::json::Json;

/// Prompt lengths used by [`prefill_sessions`]: `MIN_PROMPT ..= MAX_PROMPT`
/// tokens per session (MAX_PROMPT also bounds the `--rounds` KV check).
const MIN_PROMPT: usize = 6;
const MAX_PROMPT: usize = 10;

/// Shared-prefix arm geometry: every session repeats a PREFIX_LEN-token
/// prompt head and appends a distinct TAIL_LEN-token tail. PREFIX_LEN is
/// a multiple of PREFIX_PAGE so the whole prefix lands on full KV pages
/// and the prefix cache can map all of it.
const PREFIX_LEN: usize = 48;
const TAIL_LEN: usize = 4;
const PREFIX_PAGE: usize = 16;

/// Prefill `batch` sessions with distinct prompts; returns per-session
/// caches and the first greedy token of each.
fn prefill_sessions(engine: &Engine, batch: usize) -> Result<(Vec<KvCache>, Vec<u32>)> {
    let vocab = engine.config().vocab;
    let mut caches = Vec::with_capacity(batch);
    let mut toks = Vec::with_capacity(batch);
    for i in 0..batch {
        let prompt: Vec<u32> = (0..MIN_PROMPT + i % (MAX_PROMPT - MIN_PROMPT + 1))
            .map(|j| ((i * 131 + j * 37) % vocab) as u32)
            .collect();
        let mut cache = engine.new_cache();
        let logits = engine.prefill(&prompt, &mut cache)?;
        toks.push(Engine::argmax(&logits));
        caches.push(cache);
    }
    Ok((caches, toks))
}

/// Prefill `batch` sessions that share a common [`PREFIX_LEN`]-token
/// prefix and differ only in a [`TAIL_LEN`]-token tail; returns
/// per-session caches, first greedy tokens, and the prefill wall time.
fn prefill_shared_sessions(
    engine: &Engine,
    batch: usize,
) -> Result<(Vec<KvCache>, Vec<u32>, f64)> {
    let vocab = engine.config().vocab;
    let prefix: Vec<u32> = (0..PREFIX_LEN).map(|j| ((j * 97 + 13) % vocab) as u32).collect();
    let mut caches = Vec::with_capacity(batch);
    let mut toks = Vec::with_capacity(batch);
    let t0 = std::time::Instant::now();
    for i in 0..batch {
        let mut prompt = prefix.clone();
        prompt.extend((0..TAIL_LEN).map(|j| ((i * 131 + j * 37 + 7) % vocab) as u32));
        let mut cache = engine.new_cache();
        let logits = engine.prefill(&prompt, &mut cache)?;
        toks.push(Engine::argmax(&logits));
        caches.push(cache);
    }
    Ok((caches, toks, t0.elapsed().as_secs_f64()))
}

/// `rounds` sequential decode rounds (B GEMV chains per round); returns
/// (wall seconds, greedy streams).
fn run_sequential(
    engine: &Engine,
    caches: &mut [KvCache],
    toks: &mut [u32],
    rounds: usize,
) -> Result<(f64, Vec<Vec<u32>>)> {
    let mut streams: Vec<Vec<u32>> = toks.iter().map(|&t| vec![t]).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        for (i, cache) in caches.iter_mut().enumerate() {
            let logits = engine.decode(toks[i], cache)?;
            toks[i] = Engine::argmax(&logits);
            streams[i].push(toks[i]);
        }
    }
    Ok((t0.elapsed().as_secs_f64(), streams))
}

/// `rounds` batched decode rounds (one decode_batch call per round);
/// returns (wall seconds, greedy streams).
fn run_batched(
    engine: &Engine,
    caches: &mut [KvCache],
    toks: &mut [u32],
    rounds: usize,
) -> Result<(f64, Vec<Vec<u32>>)> {
    let mut streams: Vec<Vec<u32>> = toks.iter().map(|&t| vec![t]).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..rounds {
        let all = engine.decode_batch(toks, caches)?;
        for (i, logits) in all.iter().enumerate() {
            toks[i] = Engine::argmax(logits);
            streams[i].push(toks[i]);
        }
    }
    Ok((t0.elapsed().as_secs_f64(), streams))
}

/// `blast exp serve` — batched vs sequential decode-round A/B; writes
/// `BENCH_serve.json` (override with `--out`). Flags: `--batches 1,4,8`,
/// `--rounds N`, `--sparsity S`, `--block B`, `--quick`.
pub fn serve(args: &Args) -> Result<()> {
    let quick = args.get_bool("quick");
    let out_path = args.get_str("out", "BENCH_serve.json");
    let batches = args.get_usize_list("batches", if quick { &[1, 4] } else { &[1, 4, 8] });
    let rounds = args.get_usize("rounds", if quick { 6 } else { 16 });
    let sparsity = args.get_f64("sparsity", 0.9);
    let block = args.get_usize("block", 128);

    let cfg = fig6_config(block);
    // every round appends one token per session — validate upfront so an
    // oversized --rounds can't burn minutes of measurement and then die
    // mid-run with "KV cache full" before the report is written
    if MAX_PROMPT + rounds > cfg.max_seq {
        bail!(
            "--rounds {rounds} exceeds KV capacity: prompts up to {MAX_PROMPT} tokens + one \
             token/round must fit max_seq={} (max --rounds {})",
            cfg.max_seq,
            cfg.max_seq - MAX_PROMPT
        );
    }
    if PREFIX_LEN + TAIL_LEN + rounds > cfg.max_seq {
        bail!(
            "--rounds {rounds} exceeds KV capacity for the shared-prefix arm: \
             {PREFIX_LEN}+{TAIL_LEN} prompt tokens + one token/round must fit max_seq={} \
             (max --rounds {})",
            cfg.max_seq,
            cfg.max_seq - PREFIX_LEN - TAIL_LEN
        );
    }
    let params = fig6_params(&cfg, 42);
    let masks = random_masks(&cfg, sparsity, 77);

    let mut report = JsonReport::new("serve");
    report.meta("isa", Json::str(crate::kernels::simd::dispatch().isa.name()));
    report.meta(
        "threads",
        Json::num(crate::util::threadpool::global().workers() as f64),
    );
    report.meta("rounds", Json::num(rounds as f64));
    report.meta("sparsity", Json::num(sparsity));
    report.meta("block", Json::num(block as f64));
    let mut table = Table::new(
        "Batched vs sequential decode rounds (gate: >= 1.5x at batch >= 4, both modes)",
        &["mode", "batch", "rounds", "sequential", "batched", "speedup", "seq tok/s", "bat tok/s"],
    );
    let mut gate_ok = true;
    let mut gated_rows = 0usize;
    for mode in [MlpMode::Dense, MlpMode::Sparse] {
        let engine = Engine::new(cfg.clone(), &params, &masks, mode)?;
        for &b in &batches {
            // warmup: one round each way on throwaway sessions
            {
                let (mut c, mut t) = prefill_sessions(&engine, b)?;
                run_sequential(&engine, &mut c, &mut t, 1)?;
                let (mut c, mut t) = prefill_sessions(&engine, b)?;
                run_batched(&engine, &mut c, &mut t, 1)?;
            }
            let (mut c_seq, mut t_seq_tok) = prefill_sessions(&engine, b)?;
            let (secs_seq, streams_seq) =
                run_sequential(&engine, &mut c_seq, &mut t_seq_tok, rounds)?;
            let (mut c_bat, mut t_bat_tok) = prefill_sessions(&engine, b)?;
            let (secs_bat, streams_bat) = run_batched(&engine, &mut c_bat, &mut t_bat_tok, rounds)?;
            if streams_seq != streams_bat {
                bail!("batched decode diverged from sequential at mode={mode:?} batch={b}");
            }
            let tokens = (b * rounds) as f64;
            let speedup = secs_seq / secs_bat;
            if b >= 4 {
                gated_rows += 1;
                if speedup < 1.5 {
                    gate_ok = false;
                }
            }
            table.row(&[
                format!("{mode:?}"),
                b.to_string(),
                rounds.to_string(),
                fmt_time(secs_seq),
                fmt_time(secs_bat),
                format!("{speedup:.2}x"),
                format!("{:.1}", tokens / secs_seq),
                format!("{:.1}", tokens / secs_bat),
            ]);
            report.push(Json::obj(vec![
                ("mode", Json::str(&format!("{mode:?}"))),
                ("batch", Json::num(b as f64)),
                ("rounds", Json::num(rounds as f64)),
                ("sequential_ns", Json::num(secs_seq * 1e9)),
                ("batched_ns", Json::num(secs_bat * 1e9)),
                ("speedup", Json::num(speedup)),
                ("seq_tok_s", Json::num(tokens / secs_seq)),
                ("batched_tok_s", Json::num(tokens / secs_bat)),
                ("identical_streams", Json::Bool(true)),
            ]));
        }
    }
    // ---- shared-prefix workload arm ------------------------------------
    // B sessions repeat one page-aligned prefix with distinct tails. The
    // prefix-cache engine maps the shared pages and resumes prefill at
    // the tail; the sharing-off engine recomputes every prompt in full.
    // Greedy streams must stay bitwise identical either way, so the A/B
    // isolates the prefill compute and KV residency sharing removes.
    let pb = batches.iter().copied().max().unwrap_or(4).max(2);
    let mut ptable = Table::new(
        "Shared-prefix workload (prefix cache on vs off, bitwise-identical streams)",
        &["mode", "batch", "prefix", "prefill off", "prefill on", "speedup", "hit rate", "pages shared", "phys/logical"],
    );
    for mode in [MlpMode::Dense, MlpMode::Sparse] {
        let kv_on = KvOptions {
            page: PREFIX_PAGE,
            pool_pages: None,
            prefix_cache: true,
        };
        let kv_off = KvOptions {
            prefix_cache: false,
            ..kv_on
        };
        let shared = Engine::new_with_kv(cfg.clone(), &params, &masks, mode, kv_on)?;
        let unshared = Engine::new_with_kv(cfg.clone(), &params, &masks, mode, kv_off)?;
        let (mut c_on, mut t_on, secs_on) = prefill_shared_sessions(&shared, pb)?;
        let (mut c_off, mut t_off, secs_off) = prefill_shared_sessions(&unshared, pb)?;
        if t_on != t_off {
            bail!("shared-prefix prefill diverged from the sharing-off engine at mode={mode:?}");
        }
        // capture residency at peak prefill sharing, before decode grows
        // every session's private tail
        let stats = shared.kv_pool().prefix_stats();
        if stats.hits as usize != pb - 1 || stats.lookups as usize != pb {
            bail!(
                "prefix cache missed: expected {} hits of {} lookups, got {stats:?}",
                pb - 1,
                pb
            );
        }
        let (_, s_on) = run_batched(&shared, &mut c_on, &mut t_on, rounds)?;
        let (_, s_off) = run_batched(&unshared, &mut c_off, &mut t_off, rounds)?;
        if s_on != s_off {
            bail!("shared-prefix decode diverged from the sharing-off engine at mode={mode:?}");
        }
        let hit_rate = stats.hits as f64 / stats.lookups as f64;
        let speedup = secs_off / secs_on;
        ptable.row(&[
            format!("{mode:?}"),
            pb.to_string(),
            format!("{PREFIX_LEN}+{TAIL_LEN}"),
            fmt_time(secs_off),
            fmt_time(secs_on),
            format!("{speedup:.2}x"),
            format!("{:.0}%", hit_rate * 100.0),
            stats.pages_shared.to_string(),
            format!("{}/{}", stats.physical_pages, stats.logical_pages),
        ]);
        report.push(Json::obj(vec![
            ("arm", Json::str("shared_prefix")),
            ("mode", Json::str(&format!("{mode:?}"))),
            ("batch", Json::num(pb as f64)),
            ("prefix_len", Json::num(PREFIX_LEN as f64)),
            ("tail_len", Json::num(TAIL_LEN as f64)),
            ("unshared_prefill_ns", Json::num(secs_off * 1e9)),
            ("shared_prefill_ns", Json::num(secs_on * 1e9)),
            ("prefill_speedup", Json::num(speedup)),
            ("prefix_hits", Json::num(stats.hits as f64)),
            ("prefix_lookups", Json::num(stats.lookups as f64)),
            ("prefix_pages_shared", Json::num(stats.pages_shared as f64)),
            ("physical_pages", Json::num(stats.physical_pages as f64)),
            ("logical_pages", Json::num(stats.logical_pages as f64)),
            ("identical_streams", Json::Bool(true)),
        ]));
    }

    // ---- fleet arm: replica scaling + failover latency -----------------
    // The same synthetic load through fleets of growing width, then once
    // more with replica-kill/stall faults armed. Exactly-once delivery
    // and bitwise-identical successful streams (vs the 1-replica clean
    // run) are asserted; the chaos row additionally reports failover and
    // restart counts and the worst-case end-to-end latency — the price of
    // a failover under this engine.
    let fleet_requests = if quick { 8 } else { 16 };
    let fleet_max_new = 8usize;
    let chaos_spec = "replica_crash:0.04:11,replica_stall_ms:0.02:12:60,heartbeat_drop:0.2:13";
    let fleet_arms: &[(usize, &str)] = if quick {
        &[(1, ""), (2, ""), (2, chaos_spec)]
    } else {
        &[(1, ""), (2, ""), (4, ""), (3, chaos_spec)]
    };
    let mut ftable = Table::new(
        "Fleet scaling + failover (exactly-once; successes bitwise == 1-replica run)",
        &["replicas", "faults", "wall", "tok/s", "failovers", "restarts", "failed", "max e2e"],
    );
    let fleet_engine = Engine::new_with_kv(
        cfg.clone(),
        &params,
        &masks,
        MlpMode::Sparse,
        KvOptions { page: PREFIX_PAGE, pool_pages: Some(256), prefix_cache: true },
    )?;
    let mut expected: Option<BTreeMap<u64, Vec<u32>>> = None;
    for &(replicas, spec) in fleet_arms {
        let faults = Faults::parse(spec)?;
        let chaotic = faults.enabled();
        let fcfg = FleetConfig {
            replicas,
            batcher: BatcherConfig { max_batch: 4, max_queue: 64, ..BatcherConfig::default() },
            seed: 7,
            // tight stall threshold while stalls are injected so deposal
            // actually triggers; generous otherwise
            stall_ms: if chaotic { 50 } else { 250 },
            ..FleetConfig::default()
        };
        let mut fleet = Fleet::start_with_faults(&fleet_engine, fcfg, faults);
        let t0 = std::time::Instant::now();
        for i in 0..fleet_requests {
            fleet.submit(Request {
                id: i as u64,
                prompt: (0..8 + i % 8)
                    .map(|j| ((i * 131 + j * 17) % cfg.vocab) as u32)
                    .collect(),
                max_new: fleet_max_new,
                ..Request::default()
            })?;
        }
        let mut streams: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        let mut failed = 0usize;
        let mut max_e2e = 0f64;
        for _ in 0..fleet_requests {
            match fleet.next_completion(std::time::Duration::from_secs(120)) {
                CompletionWait::Ready(c) => {
                    max_e2e = max_e2e.max(c.e2e_secs);
                    if c.error.is_some() {
                        failed += 1;
                    } else if streams.insert(c.id, c.tokens).is_some() {
                        bail!("fleet arm: request {} answered twice", c.id);
                    }
                }
                other => bail!("fleet arm ended early: {other:?}"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        match &expected {
            None => expected = Some(streams.clone()),
            Some(exp) => {
                for (id, toks) in &streams {
                    if exp.get(id) != Some(toks) {
                        bail!(
                            "fleet arm (replicas={replicas}, faults={spec:?}): stream of \
                             request {id} diverged from the 1-replica run"
                        );
                    }
                }
            }
        }
        let fm = fleet.metrics();
        fleet.stop();
        let undrained: usize = fleet.pools().iter().map(|p| p.pages_in_use()).sum();
        if undrained > 0 {
            bail!("fleet arm (replicas={replicas}): {undrained} KV pages resident after stop");
        }
        let tokens: usize = streams.values().map(|s| s.len()).sum();
        ftable.row(&[
            replicas.to_string(),
            if chaotic { "armed" } else { "-" }.to_string(),
            fmt_time(wall),
            format!("{:.1}", tokens as f64 / wall),
            fm.failovers.to_string(),
            fm.restarts.to_string(),
            failed.to_string(),
            format!("{:.1}ms", max_e2e * 1e3),
        ]);
        report.push(Json::obj(vec![
            ("arm", Json::str("fleet")),
            ("replicas", Json::num(replicas as f64)),
            ("faults", Json::str(spec)),
            ("requests", Json::num(fleet_requests as f64)),
            ("wall_ns", Json::num(wall * 1e9)),
            ("tok_s", Json::num(tokens as f64 / wall)),
            ("failovers", Json::num(fm.failovers as f64)),
            ("restarts", Json::num(fm.restarts as f64)),
            ("deposed_stalls", Json::num(fm.deposed_stalls as f64)),
            ("failed", Json::num(failed as f64)),
            ("max_e2e_ms", Json::num(max_e2e * 1e3)),
            ("identical_streams", Json::Bool(true)),
        ]));
    }

    table.print();
    println!();
    ptable.print();
    println!();
    ftable.print();
    report.write(std::path::Path::new(&out_path))?;
    println!("\nwrote {} rows to {out_path}", report.len());
    println!(
        "gate (batched >= 1.5x sequential at batch >= 4, dense & sparse): {}",
        if gated_rows == 0 {
            "N/A — no batch >= 4 measured (pass --batches with a value >= 4)"
        } else if gate_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelKind, NativeConfig};
    use crate::model::params::ParamStore;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn tiny() -> (NativeConfig, ParamStore) {
        let cfg = NativeConfig {
            name: "serve-ab-test".into(),
            kind: ModelKind::Llama,
            vocab: 32,
            emb: 16,
            ffn: 32,
            layers: 1,
            heads: 2,
            max_seq: 32,
            block: 8,
        };
        let mut rng = Rng::new(9);
        let mut s = ParamStore::new();
        let e = cfg.emb;
        s.insert("tok_emb".into(), Tensor::randn(&[cfg.vocab, e], 0.1, &mut rng));
        for i in 0..cfg.layers {
            let p = |n: &str| format!("layer{i}.{n}");
            s.insert(p("ln1"), Tensor::full(&[e], 1.0));
            for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
                s.insert(p(w), Tensor::randn(&[e, e], 0.1, &mut rng));
            }
            s.insert(p("ln2"), Tensor::full(&[e], 1.0));
            for (n, r, c) in cfg.mlp_shapes() {
                s.insert(p(n), Tensor::randn(&[r, c], 0.1, &mut rng));
            }
        }
        s.insert("final_norm".into(), Tensor::full(&[e], 1.0));
        s.insert("lm_head".into(), Tensor::randn(&[e, cfg.vocab], 0.1, &mut rng));
        (cfg, s)
    }

    #[test]
    fn harness_paths_agree_on_tiny_engine() {
        let (cfg, params) = tiny();
        let engine = Engine::new(cfg, &params, &BTreeMap::new(), MlpMode::Sparse).unwrap();
        let (mut c1, mut t1) = prefill_sessions(&engine, 3).unwrap();
        let (_, s_seq) = run_sequential(&engine, &mut c1, &mut t1, 4).unwrap();
        let (mut c2, mut t2) = prefill_sessions(&engine, 3).unwrap();
        let (_, s_bat) = run_batched(&engine, &mut c2, &mut t2, 4).unwrap();
        assert_eq!(s_seq, s_bat);
        assert_eq!(s_seq.len(), 3);
        assert!(s_seq.iter().all(|s| s.len() == 5)); // prefill token + 4 rounds
    }

    /// The shared-prefix arm only shares what lands on *full* pages, so
    /// its prefix must stay page-aligned and its prompts must fit the
    /// fig6 serving config alongside the default round counts.
    #[test]
    fn prefix_arm_geometry_is_page_aligned_and_fits() {
        assert_eq!(PREFIX_LEN % PREFIX_PAGE, 0);
        assert!(TAIL_LEN > 0, "tails must diverge after the shared prefix");
        assert!(PREFIX_LEN + TAIL_LEN + 16 <= fig6_config(128).max_seq);
    }
}
