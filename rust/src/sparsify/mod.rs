//! Blocked prune-and-grow — the paper's §3.2 algorithm, run by the L3
//! coordinator between AOT `train_step` executions.
//!
//! * [`schedule`] — the cubic sparsity schedule `s(i)` (paper Eq. 2).
//! * [`prune`] — the pruning function `S()` (block Frobenius norms →
//!   keep-top-k), the gradient-driven grow step (set difference + regrow),
//!   and the regrown-block statistics behind Fig. 10.
//! * [`controller`] — the stateful controller: owns the per-weight masks,
//!   decides *when* to update (`step_size`), applies the dense-layer
//!   placement policy (`L` layers kept dense, Fig. 11), zeroes regrown
//!   blocks in the dense weights, and records history.

pub mod controller;
pub mod prune;
pub mod schedule;

pub use controller::{MaskUpdate, PruneGrowConfig, PruneGrowController};
pub use prune::{block_frobenius_norms, generate_mask, top_k_mask, GrowStats};
pub use schedule::SparsitySchedule;
