//! The pruning function `S()` and the grow step (paper §3.2, Figure 2).
//!
//! `S()` interprets a matrix as a grid of `b×b` blocks, ranks blocks by
//! Frobenius norm, and keeps the top `(1 - s) · n_blocks`. The grow step
//! applies the *same* `S()` to the gradient matrix and regrows the set
//! difference `D = S(G) \ S(W)` into the new mask; regrown blocks are
//! zero-initialized by the controller so they do not perturb the transform
//! until the optimizer updates them.

use crate::sparse::BlockMask;
use crate::tensor::Tensor;

/// Frobenius norm of every `b×b` block; returns an `(rb, cb)` tensor.
pub fn block_frobenius_norms(w: &Tensor, block: usize) -> Tensor {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(k % block, 0, "rows {k} % block {block}");
    assert_eq!(n % block, 0, "cols {n} % block {block}");
    let (rb, cb) = (k / block, n / block);
    let mut out = vec![0.0f32; rb * cb];
    let data = w.data();
    for br in 0..rb {
        for i in 0..block {
            let row = (br * block + i) * n;
            for bc in 0..cb {
                let mut acc = 0.0f32;
                for &v in &data[row + bc * block..row + bc * block + block] {
                    acc += v * v;
                }
                out[br * cb + bc] += acc;
            }
        }
    }
    for v in &mut out {
        *v = v.sqrt();
    }
    Tensor::new(&[rb, cb], out)
}

/// `S()`: keep the `keep` largest-norm blocks (ties broken by index for
/// determinism). `norms` is the `(rb, cb)` block-norm grid.
pub fn top_k_mask(norms: &Tensor, keep: usize) -> BlockMask {
    let (rb, cb) = (norms.shape()[0], norms.shape()[1]);
    let total = rb * cb;
    let keep = keep.min(total);
    let mut idx: Vec<usize> = (0..total).collect();
    let d = norms.data();
    idx.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap().then(a.cmp(&b)));
    let mut bits = vec![false; total];
    for &i in idx.iter().take(keep) {
        bits[i] = true;
    }
    BlockMask::from_bits(rb, cb, bits)
}

/// Statistics of one prune-and-grow application (Fig. 10's series).
#[derive(Clone, Copy, Debug, Default)]
pub struct GrowStats {
    pub total_blocks: usize,
    pub kept_by_weight: usize,
    pub regrown: usize,
    /// Fraction of the *new mask's* blocks that came from the grow step.
    pub regrown_ratio: f64,
    /// Realized sparsity of the new mask (≤ target because of regrowth).
    pub realized_sparsity: f64,
}

/// One full `generate_masks()` step for a single weight matrix:
///
/// 1. `S(W)` — magnitude top-k at target sparsity `s`.
/// 2. `S(G)` — gradient top-k at the same sparsity.
/// 3. `D = S(G) \ S(W)` — high-gradient blocks magnitude pruning would drop.
/// 4. new mask = `S(W) ∪ D`.
///
/// Returns the new mask, the regrow set `D` (whose blocks the controller
/// zero-initializes), and the stats.
pub fn generate_mask(
    w: &Tensor,
    g: &Tensor,
    block: usize,
    sparsity: f64,
) -> (BlockMask, BlockMask, GrowStats) {
    assert!((0.0..=1.0).contains(&sparsity));
    let w_norms = block_frobenius_norms(w, block);
    let g_norms = block_frobenius_norms(g, block);
    let total = w_norms.len();
    let keep = total - ((sparsity * total as f64).floor() as usize).min(total);
    let sw = top_k_mask(&w_norms, keep);
    let sg = top_k_mask(&g_norms, keep);
    let d = sg.difference(&sw);
    let new_mask = sw.union(&d);
    let stats = GrowStats {
        total_blocks: total,
        kept_by_weight: sw.nnzb(),
        regrown: d.nnzb(),
        regrown_ratio: d.nnzb() as f64 / new_mask.nnzb().max(1) as f64,
        realized_sparsity: new_mask.sparsity(),
    };
    (new_mask, d, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::prop_assert;
    use crate::util::rng::Rng;

    #[test]
    fn norms_identify_hot_block() {
        let mut w = Tensor::zeros(&[8, 8]);
        // make block (1, 0) hot
        for i in 4..8 {
            for j in 0..4 {
                w.set2(i, j, 10.0);
            }
        }
        let n = block_frobenius_norms(&w, 4);
        assert_eq!(n.shape(), &[2, 2]);
        assert!(n.at2(1, 0) > 39.0);
        assert_eq!(n.at2(0, 0), 0.0);
    }

    #[test]
    fn top_k_deterministic_on_ties() {
        let norms = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let m = top_k_mask(&norms, 2);
        assert!(m.get(0, 0) && m.get(0, 1));
        assert!(!m.get(1, 0) && !m.get(1, 1));
    }

    #[test]
    fn generate_mask_invariants() {
        prop::check_default("prune-grow-invariants", |rng| {
            let b = *prop::pick(rng, &[2, 4]);
            let rb = prop::usize_in(rng, 2, 8);
            let cb = prop::usize_in(rng, 2, 8);
            let w = Tensor::randn(&[rb * b, cb * b], 1.0, rng);
            let g = Tensor::randn(&[rb * b, cb * b], 1.0, rng);
            let s = rng.f64() * 0.95;
            let (mask, regrow, stats) = generate_mask(&w, &g, b, s);
            let total = rb * cb;
            let keep = total - (s * total as f64).floor() as usize;

            // invariant 1: mask ⊇ S(W), so nnzb >= keep
            prop_assert!(mask.nnzb() >= keep, "mask lost magnitude blocks");
            // invariant 2: regrow ⊆ mask and disjoint from S(W)
            prop_assert!(regrow.difference(&mask).nnzb() == 0, "regrow ⊄ mask");
            // invariant 3: realized sparsity ≤ target (regrowth only adds)
            prop_assert!(
                stats.realized_sparsity <= s + 1e-9,
                "realized {} > target {s}",
                stats.realized_sparsity
            );
            // invariant 4: mask size = keep + regrown
            prop_assert!(
                mask.nnzb() == keep + stats.regrown,
                "{} != {keep} + {}",
                mask.nnzb(),
                stats.regrown
            );
            // invariant 5: at most keep blocks regrown (|S(G)| = keep)
            prop_assert!(stats.regrown <= keep, "regrown > |S(G)|");
            Ok(())
        });
    }

    #[test]
    fn identical_w_and_g_regrows_nothing() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let (_, regrow, stats) = generate_mask(&w, &w, 4, 0.5);
        assert_eq!(regrow.nnzb(), 0);
        assert_eq!(stats.regrown_ratio, 0.0);
        assert!((stats.realized_sparsity - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_sparsity_keeps_everything() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let g = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let (mask, _, _) = generate_mask(&w, &g, 4, 0.0);
        assert_eq!(mask.nnzb(), 4);
    }
}
