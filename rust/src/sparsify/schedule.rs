//! The cubic sparsity schedule (paper Eq. 2, after Zhu & Gupta 2017):
//!
//! ```text
//! s(i) = s_max + (s_init - s_max) * (1 - i / (m - d))^3
//! ```
//!
//! `s_init` is the starting sparsity, `m` the total number of training
//! iterations, and `d` the decay term: larger `d` reaches `s_max` earlier,
//! which activates the BSpMM routines earlier in pretraining (Table 6 shows
//! accuracy is robust to this).

#[derive(Clone, Copy, Debug)]
pub struct SparsitySchedule {
    pub s_init: f64,
    pub s_max: f64,
    /// Total training iterations `m`.
    pub total_iters: usize,
    /// Decay term `d` (must be < total_iters).
    pub decay: usize,
}

impl SparsitySchedule {
    pub fn new(s_init: f64, s_max: f64, total_iters: usize, decay: usize) -> Self {
        assert!((0.0..=1.0).contains(&s_init));
        assert!((0.0..=1.0).contains(&s_max));
        assert!(s_init <= s_max, "schedule must be non-decreasing");
        assert!(decay < total_iters, "decay {decay} >= total {total_iters}");
        SparsitySchedule {
            s_init,
            s_max,
            total_iters,
            decay,
        }
    }

    /// Target sparsity at iteration `i` (clamped to `s_max` once
    /// `i >= m - d`).
    pub fn sparsity_at(&self, i: usize) -> f64 {
        let horizon = (self.total_iters - self.decay) as f64;
        if i as f64 >= horizon {
            return self.s_max;
        }
        let base = 1.0 - i as f64 / horizon;
        self.s_max + (self.s_init - self.s_max) * base * base * base
    }

    /// First iteration at which `s(i) >= threshold` (e.g. the 60% point
    /// where the paper's runtime switches from dense GEMM to BSpMM).
    pub fn first_iter_reaching(&self, threshold: f64) -> Option<usize> {
        (0..=self.total_iters).find(|&i| self.sparsity_at(i) >= threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testkit::prop;

    /// Fuzzed `(s_init, s_max, m, d)`: the schedule is monotone
    /// non-decreasing over `0..=m`, stays inside `[s_init, s_max]`, and
    /// clamps exactly at `s_max` from iteration `m − d` onward.
    #[test]
    fn monotone_and_clamped_property() {
        prop::check_default("sparsity-schedule", |rng| {
            let s_init = rng.f64() * 0.5;
            let s_max = s_init + rng.f64() * (1.0 - s_init);
            let m = prop::usize_in(rng, 2, 400);
            let d = prop::usize_in(rng, 0, m - 1);
            let s = SparsitySchedule::new(s_init, s_max, m, d);
            prop_assert!(
                (s.sparsity_at(0) - s_init).abs() < 1e-12,
                "s(0) {} != s_init {s_init}",
                s.sparsity_at(0)
            );
            let mut prev = f64::NEG_INFINITY;
            for i in 0..=m {
                let v = s.sparsity_at(i);
                prop_assert!(v >= prev - 1e-12, "decreased at {i}: {prev} -> {v}");
                prop_assert!(
                    v >= s_init - 1e-12 && v <= s_max + 1e-12,
                    "out of range at {i}: {v}"
                );
                prev = v;
            }
            // exact clamp at and beyond the horizon m − d
            for i in (m - d)..=(m + 5) {
                prop_assert!(
                    s.sparsity_at(i) == s_max,
                    "not clamped at {i} (horizon {})",
                    m - d
                );
            }
            // first_iter_reaching is consistent with the pointwise values
            if let Some(t) = s.first_iter_reaching(s_max) {
                prop_assert!(s.sparsity_at(t) >= s_max - 1e-12, "reach point wrong");
                prop_assert!(
                    t == 0 || s.sparsity_at(t - 1) < s_max,
                    "not the first reach point"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn endpoints() {
        let s = SparsitySchedule::new(0.0, 0.8, 10_000, 0);
        assert!((s.sparsity_at(0) - 0.0).abs() < 1e-12);
        assert!((s.sparsity_at(10_000) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn monotone_nondecreasing() {
        let s = SparsitySchedule::new(0.1, 0.95, 1_000, 100);
        let mut prev = -1.0;
        for i in 0..=1_000 {
            let v = s.sparsity_at(i);
            assert!(v >= prev - 1e-12, "decreased at {i}");
            assert!(v <= 0.95 + 1e-12);
            prev = v;
        }
    }

    #[test]
    fn decay_reaches_max_earlier() {
        let slow = SparsitySchedule::new(0.0, 0.8, 10_000, 0);
        let fast = SparsitySchedule::new(0.0, 0.8, 10_000, 9_000);
        let t_slow = slow.first_iter_reaching(0.6).unwrap();
        let t_fast = fast.first_iter_reaching(0.6).unwrap();
        assert!(
            t_fast < t_slow,
            "d=9000 should reach 60% earlier ({t_fast} vs {t_slow})"
        );
        // with d = 9000, s_max holds from iteration m - d = 1000 on
        assert!((fast.sparsity_at(1_000) - 0.8).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_decreasing_schedule() {
        SparsitySchedule::new(0.9, 0.5, 100, 0);
    }
}
