//! The stateful prune-and-grow controller — the L3 piece that turns the
//! paper's Listing 1 into a service the trainer calls between AOT
//! `train_step` executions:
//!
//! ```text
//! for iteration in range(train_iters):
//!     forward_and_backward_step()          # runtime::Executable (HLO)
//!     if iteration % step_size == 0:
//!         generate_masks()                 # PruneGrowController::update
//!         prune_weights()                  #   + BlockMask application
//! ```

use std::collections::BTreeMap;

use crate::sparse::BlockMask;
use crate::sparsify::prune::{generate_mask, GrowStats};
use crate::sparsify::schedule::SparsitySchedule;
use crate::tensor::Tensor;

/// Which MLP blocks stay dense (Fig. 11 / the `L` hyper-parameter in
/// Table 2). The paper finds keeping the *rightmost* (last) layers dense
/// preserves perplexity best.
#[derive(Clone, Copy, Debug, Default)]
pub struct DensePolicy {
    pub left: usize,
    pub right: usize,
}

impl DensePolicy {
    pub fn right_only(l: usize) -> Self {
        DensePolicy { left: 0, right: l }
    }

    pub fn is_dense(&self, layer: usize, n_layers: usize) -> bool {
        layer < self.left || layer >= n_layers.saturating_sub(self.right)
    }
}

/// One sparsifiable weight matrix the controller tracks.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub layer: usize,
    /// Block-grid shape of the mask.
    pub rb: usize,
    pub cb: usize,
}

#[derive(Clone, Debug)]
pub struct PruneGrowConfig {
    pub block: usize,
    pub schedule: SparsitySchedule,
    /// Mask regeneration interval (Listing 1's `step_size`, Table 5).
    pub step_size: usize,
    pub dense_policy: DensePolicy,
    pub n_layers: usize,
}

/// Result of one `generate_masks()` + `prune_weights()` application.
#[derive(Clone, Debug, Default)]
pub struct MaskUpdate {
    /// Per-weight regrow sets — blocks the trainer must zero in the dense
    /// weights (paper: regrown blocks start at zero).
    pub regrown: BTreeMap<String, BlockMask>,
    /// Aggregated over all updated weights.
    pub stats: GrowStats,
    pub target_sparsity: f64,
    pub iteration: usize,
}

pub struct PruneGrowController {
    cfg: PruneGrowConfig,
    specs: Vec<WeightSpec>,
    masks: BTreeMap<String, BlockMask>,
    /// (iteration, aggregated stats) per update — Fig. 10's series.
    history: Vec<MaskUpdate>,
}

impl PruneGrowController {
    pub fn new(cfg: PruneGrowConfig, specs: Vec<WeightSpec>) -> Self {
        let masks = specs
            .iter()
            .map(|s| (s.name.clone(), BlockMask::ones(s.rb, s.cb)))
            .collect();
        PruneGrowController {
            cfg,
            specs,
            masks,
            history: Vec::new(),
        }
    }

    pub fn config(&self) -> &PruneGrowConfig {
        &self.cfg
    }

    pub fn masks(&self) -> &BTreeMap<String, BlockMask> {
        &self.masks
    }

    /// Replace the live masks with checkpointed ones (the trainer's
    /// resume path). Every tracked weight must be present with its spec's
    /// grid shape; update history is not restored — it is diagnostics
    /// only, and the schedule is a pure function of config + iteration.
    pub fn restore_masks(
        &mut self,
        masks: BTreeMap<String, BlockMask>,
    ) -> anyhow::Result<()> {
        for spec in &self.specs {
            let m = masks.get(&spec.name).ok_or_else(|| {
                anyhow::anyhow!("checkpoint is missing mask for {:?}", spec.name)
            })?;
            anyhow::ensure!(
                m.rb == spec.rb && m.cb == spec.cb,
                "mask {:?} has grid {}x{}, expected {}x{}",
                spec.name,
                m.rb,
                m.cb,
                spec.rb,
                spec.cb
            );
        }
        self.masks = masks;
        Ok(())
    }

    pub fn history(&self) -> &[MaskUpdate] {
        &self.history
    }

    /// Is this weight exempted by the dense-layer policy?
    pub fn is_dense_layer(&self, spec: &WeightSpec) -> bool {
        self.cfg
            .dense_policy
            .is_dense(spec.layer, self.cfg.n_layers)
    }

    /// Listing 1's `iteration % step_size == 0` gate.
    pub fn should_update(&self, iteration: usize) -> bool {
        iteration % self.cfg.step_size == 0
    }

    /// Target sparsity at `iteration` (Eq. 2).
    pub fn target_sparsity(&self, iteration: usize) -> f64 {
        self.cfg.schedule.sparsity_at(iteration)
    }

    /// Run `generate_masks()` for every sparsifiable weight. `weights` and
    /// `grads` are dense matrices keyed by name (fetched from the device by
    /// the trainer). Returns the update to apply (regrown blocks to zero).
    pub fn update(
        &mut self,
        iteration: usize,
        weights: &BTreeMap<String, Tensor>,
        grads: &BTreeMap<String, Tensor>,
    ) -> MaskUpdate {
        let s = self.target_sparsity(iteration);
        self.update_with_target(iteration, s, weights, grads)
    }

    /// [`update`](Self::update) with an explicit target sparsity instead
    /// of the scheduled one — the guarded trainer retries a reverted mask
    /// update at lower aggression by passing a target below the schedule.
    pub fn update_with_target(
        &mut self,
        iteration: usize,
        s: f64,
        weights: &BTreeMap<String, Tensor>,
        grads: &BTreeMap<String, Tensor>,
    ) -> MaskUpdate {
        let mut upd = MaskUpdate {
            target_sparsity: s,
            iteration,
            ..Default::default()
        };
        let mut agg = GrowStats::default();
        let mut n_updated = 0usize;
        for spec in &self.specs {
            if self.cfg.dense_policy.is_dense(spec.layer, self.cfg.n_layers) {
                continue; // mask stays all-ones
            }
            let w = weights
                .get(&spec.name)
                .unwrap_or_else(|| panic!("missing weight {}", spec.name));
            let g = grads
                .get(&spec.name)
                .unwrap_or_else(|| panic!("missing grad {}", spec.name));
            let (mask, regrow, stats) = generate_mask(w, g, self.cfg.block, s);
            // regrown = blocks newly enabled that were PRUNED under the old
            // mask; blocks that stayed active keep their trained values.
            let old = &self.masks[&spec.name];
            let newly_enabled = mask.difference(old);
            let to_zero = regrow.difference(old).union(&newly_enabled.difference(&regrow));
            upd.regrown.insert(spec.name.clone(), to_zero);
            self.masks.insert(spec.name.clone(), mask);
            agg.total_blocks += stats.total_blocks;
            agg.kept_by_weight += stats.kept_by_weight;
            agg.regrown += stats.regrown;
            agg.realized_sparsity += stats.realized_sparsity;
            n_updated += 1;
        }
        if n_updated > 0 {
            agg.realized_sparsity /= n_updated as f64;
            agg.regrown_ratio = agg.regrown as f64
                / (agg.kept_by_weight + agg.regrown).max(1) as f64;
        }
        upd.stats = agg;
        self.history.push(upd.clone());
        upd
    }

    /// Revert the most recent [`update`](Self::update): restore the
    /// caller's pre-update mask snapshot and drop the update from the
    /// history so the Fig. 10 series only records updates that stuck.
    /// The caller is responsible for restoring the weight blocks the
    /// update zeroed (see `BlockMask::gather_blocks`).
    pub fn undo_last_update(
        &mut self,
        masks: BTreeMap<String, BlockMask>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(!self.history.is_empty(), "no mask update to undo");
        self.restore_masks(masks)?;
        self.history.pop();
        Ok(())
    }

    /// Mean realized sparsity across all tracked masks (dense-policy layers
    /// included — this is what the runtime's kernel-selection threshold and
    /// the memory model see).
    pub fn mean_sparsity(&self) -> f64 {
        if self.masks.is_empty() {
            return 0.0;
        }
        self.masks.values().map(|m| m.sparsity()).sum::<f64>() / self.masks.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn specs_2layer(rb: usize, cb: usize) -> Vec<WeightSpec> {
        (0..2)
            .flat_map(|l| {
                ["w1", "w3"].iter().map(move |w| WeightSpec {
                    name: format!("layer{l}.mlp.{w}"),
                    layer: l,
                    rb,
                    cb,
                })
            })
            .collect()
    }

    fn tensors(specs: &[WeightSpec], block: usize, seed: u64) -> BTreeMap<String, Tensor> {
        let mut rng = Rng::new(seed);
        specs
            .iter()
            .map(|s| {
                (
                    s.name.clone(),
                    Tensor::randn(&[s.rb * block, s.cb * block], 1.0, &mut rng),
                )
            })
            .collect()
    }

    fn controller(step_size: usize, policy: DensePolicy) -> PruneGrowController {
        PruneGrowController::new(
            PruneGrowConfig {
                block: 4,
                schedule: SparsitySchedule::new(0.0, 0.75, 100, 0),
                step_size,
                dense_policy: policy,
                n_layers: 2,
            },
            specs_2layer(4, 4),
        )
    }

    #[test]
    fn starts_fully_dense() {
        let c = controller(10, DensePolicy::default());
        assert_eq!(c.mean_sparsity(), 0.0);
        assert!(c.masks().values().all(|m| m.nnzb() == 16));
    }

    #[test]
    fn sparsity_follows_schedule() {
        let mut c = controller(1, DensePolicy::default());
        let specs = specs_2layer(4, 4);
        for it in [0usize, 25, 50, 75, 99] {
            let w = tensors(&specs, 4, it as u64);
            let g = tensors(&specs, 4, it as u64 + 1000);
            let upd = c.update(it, &w, &g);
            // realized ≤ target, and reasonably close for random norms
            assert!(upd.stats.realized_sparsity <= upd.target_sparsity + 1e-9);
        }
        // by iteration 99 the schedule is ~0.75
        assert!(c.target_sparsity(99) > 0.74);
    }

    #[test]
    fn dense_policy_exempts_layers() {
        let mut c = controller(1, DensePolicy::right_only(1));
        let specs = specs_2layer(4, 4);
        let w = tensors(&specs, 4, 1);
        let g = tensors(&specs, 4, 2);
        c.update(90, &w, &g);
        // layer1 (rightmost) stays dense, layer0 got pruned
        assert_eq!(c.masks()["layer1.mlp.w1"].sparsity(), 0.0);
        assert!(c.masks()["layer0.mlp.w1"].sparsity() > 0.5);
    }

    #[test]
    fn step_size_gate() {
        let c = controller(25, DensePolicy::default());
        assert!(c.should_update(0));
        assert!(!c.should_update(13));
        assert!(c.should_update(50));
    }

    #[test]
    fn regrown_blocks_are_newly_enabled_only() {
        let mut c = controller(1, DensePolicy::default());
        let specs = specs_2layer(4, 4);
        let w = tensors(&specs, 4, 3);
        let g = tensors(&specs, 4, 4);
        c.update(50, &w, &g); // establishes a sparse mask
        let before = c.masks().clone();
        let w2 = tensors(&specs, 4, 5);
        let g2 = tensors(&specs, 4, 6);
        let upd = c.update(60, &w2, &g2);
        for (name, to_zero) in &upd.regrown {
            // every to-zero block must be enabled in the new mask and
            // disabled in the old one
            let new_mask = &c.masks()[name];
            let old = &before[name];
            assert_eq!(to_zero.difference(new_mask).nnzb(), 0);
            for r in 0..to_zero.rb {
                for cc in 0..to_zero.cb {
                    if to_zero.get(r, cc) {
                        assert!(!old.get(r, cc), "{name}: zeroing an already-active block");
                    }
                }
            }
        }
        let _ = upd;
    }

    #[test]
    fn update_with_target_overrides_schedule_and_undo_reverts() {
        let mut c = controller(1, DensePolicy::default());
        let specs = specs_2layer(4, 4);
        let w = tensors(&specs, 4, 7);
        let g = tensors(&specs, 4, 8);
        let before = c.masks().clone();
        // schedule at iter 99 is ~0.75, but ask for a gentler 0.25
        let upd = c.update_with_target(99, 0.25, &w, &g);
        assert!(upd.target_sparsity <= 0.25 + 1e-9);
        assert!(c.mean_sparsity() <= 0.25 + 1e-9);
        assert_eq!(c.history().len(), 1);
        c.undo_last_update(before.clone()).unwrap();
        assert_eq!(c.masks(), &before);
        assert!(c.history().is_empty());
        // nothing left to undo
        assert!(c.undo_last_update(before).is_err());
    }

    #[test]
    fn history_records_every_update() {
        let mut c = controller(1, DensePolicy::default());
        let specs = specs_2layer(4, 4);
        for it in 0..5 {
            let w = tensors(&specs, 4, it);
            let g = tensors(&specs, 4, it + 99);
            c.update(it as usize, &w, &g);
        }
        assert_eq!(c.history().len(), 5);
    }
}
