//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so the subset of
//! anyhow this project actually uses — [`Error`], [`Result`], the
//! [`Context`] extension trait and the [`anyhow!`]/[`bail!`] macros — is
//! implemented here and wired in as a path dependency. Semantics match
//! anyhow where call sites can observe them:
//!
//! * `{e}` prints the outermost message, `{e:#}` the full cause chain
//!   joined with `": "`, `{e:?}` the chain as well;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` value
//!   (capturing its `source()` chain);
//! * `.context(..)` / `.with_context(..)` work on both `Result` and
//!   `Option` and prepend a new outermost message.
//!
//! [`Error`] deliberately does **not** implement `std::error::Error`
//! (exactly like the real crate) so that the blanket `From` impl does not
//! collide with the reflexive `From<Error> for Error`.

use std::fmt;

/// A lightweight error: an ordered cause chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Prepend a new outermost message to the cause chain.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut cur: Option<&dyn std::error::Error> = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_prepends_outermost() {
        let e = fails_io().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(format!("{e:?}"), "reading manifest: gone");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        let e = none.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        let none: Option<u32> = None;
        let e = none.with_context(|| format!("missing {}", "k")).unwrap_err();
        assert_eq!(format!("{e}"), "missing k");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let v = 42;
        let e = anyhow!("value {v} and {}", "arg");
        assert_eq!(format!("{e}"), "value 42 and arg");
        fn bails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 7);
            }
            Ok(1)
        }
        assert_eq!(format!("{}", bails(true).unwrap_err()), "flagged 7");
        assert_eq!(bails(false).unwrap(), 1);
    }
}
